"""Fleet serving layer: affinity, plan distribution, failure containment,
backpressure, and telemetry rollup.

The load-bearing quartet:

  * ``test_affinity_invariant_1k_frames`` — across 1000 frames a warm
    stream's frames land on exactly one worker (``streams_served`` evidence
    on every worker) and the affinity table never silently moves.
  * ``test_worker_kill_quarantines_exactly_victim_streams`` — a worker
    death resets precisely its own streams' carries; survivors' carry
    objects are untouched (asserted by identity), and every migration in
    ``rebalance_log`` was preceded by a quarantine.
  * ``test_mixed_plan_hash_rejected_at_construction`` — a fleet whose
    workers disagree on ``plan_hash`` never comes up.
  * ``test_router_sheds_before_worker_queue_overflows`` — under a wedged
    worker the router's ``max_worker_queue`` bound fires (structured
    ``FleetSaturated``) while the worker's own request queue stays far
    from capacity.

Everything is scheduling-order independent: the watchdog thread is
disabled (``health_interval_s=None``) and failures are injected or
triggered synchronously.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import BGConfig
from repro.fleet import (
    FleetRouter,
    FleetSaturated,
    FleetWatchdog,
    LocalWorker,
    PlanController,
    PlanMismatch,
)
from repro.plan import plan_for
from repro.plan_cache import PlanCache
from repro.reliability import Fault, FaultInjector, FaultPlan
from repro.serving import EngineStats

CFG = BGConfig(r=4, sigma_s=4.0, sigma_r=60.0)
H, W = 24, 32
ALPHA = 0.6


def _controller(streams_per_worker=4, **kw):
    return PlanController(
        cfg=CFG, height=H, width=W,
        streams_per_worker=streams_per_worker, temporal=True,
        sharded=False, **kw,
    )


def _fleet(n_workers=2, **kw):
    kw.setdefault("health_interval_s", None)  # deterministic: no poller
    kw.setdefault("worker_kwargs", dict(max_batch=8, batch_window_ms=1.0))
    kw.setdefault("controller", _controller())
    return FleetRouter(n_workers=n_workers, **kw)


def _frame(seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 255.0, size=(H, W)).astype(np.float32)


# --------------------------------------------------------------- affinity
def test_affinity_invariant_1k_frames():
    """1000 frames over 8 warm streams on 2 workers: every stream's frames
    land on exactly the worker its affinity entry names, and nothing ever
    migrates (no failures -> empty rebalance_log)."""
    n_streams, rounds = 8, 125
    frames = [_frame(s) for s in range(n_streams)]
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(n_streams)}
        assert set(pins.values()) <= {0, 1}
        for t in range(rounds):
            futs = [
                router.submit(frames[s], stream_id=s)
                for s in range(n_streams)
            ]
            for f in futs:
                assert np.isfinite(np.asarray(f.result())).all()
            # the pin never moves while the stream is warm
            assert {s: router.stream_worker(s) for s in range(n_streams)} \
                == pins
        # per-worker accounting: each stream served by exactly its pin
        for s in range(n_streams):
            served_on = {
                w.wid for w in router.workers
                if w.streams_served.get(s, 0) > 0
            }
            assert served_on == {pins[s]}, (s, served_on, pins[s])
            assert router.workers[pins[s]].streams_served[s] == rounds
        assert router.rebalance_log == []
        assert router.rebalanced_streams == 0
        st = router.stats()
        assert st.merged.completed == n_streams * rounds
        assert st.merged.failed == 0


def test_temporal_fleet_requires_stream_id():
    with _fleet(n_workers=2) as router:
        with pytest.raises(ValueError, match="stream_id"):
            router.submit(_frame(0))
        with pytest.raises(KeyError):
            router.submit(_frame(0), stream_id="never-opened")


# ------------------------------------------------------ failure containment
def test_worker_kill_quarantines_exactly_victim_streams():
    """Killing one worker resets exactly its streams (cold restart on the
    survivor); surviving streams keep their carry objects untouched."""
    n_streams = 6
    frames = [_frame(100 + s) for s in range(n_streams)]
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(n_streams)}
        # warm every stream: two rounds so every carry is non-None
        for _ in range(2):
            for f in [router.submit(frames[s], stream_id=s)
                      for s in range(n_streams)]:
                f.result()
        victim_wid = pins[0]
        survivor = next(w for w in router.workers if w.wid != victim_wid)
        victims = sorted(s for s, w in pins.items() if w == victim_wid)
        keepers = sorted(s for s, w in pins.items() if w != victim_wid)
        assert victims and keepers, "rendezvous split both ways"
        kept_carries = {
            s: survivor.packer.sessions[s].carry for s in keepers
        }
        assert all(c is not None for c in kept_carries.values())

        moved = router.fail_worker(victim_wid)

        # exactly the victim's streams moved, each preceded by a quarantine
        assert sorted(s for s, _ in moved) == victims
        assert router.quarantined_streams == len(victims)
        assert router.rebalanced_streams == len(victims)
        assert sorted(s for s, _, _ in router.rebalance_log) == victims
        for s, old, new in router.rebalance_log:
            assert old == victim_wid and new == survivor.wid
        # victims restart cold on the survivor...
        for s in victims:
            assert router.stream_worker(s) == survivor.wid
            assert survivor.packer.sessions[s].carry is None
        # ...while survivors' carries are the very same objects
        for s in keepers:
            assert survivor.packer.sessions[s].carry is kept_carries[s]
        # the fleet still serves every stream
        for f in [router.submit(frames[s], stream_id=s)
                  for s in range(n_streams)]:
            assert np.isfinite(np.asarray(f.result())).all()
        assert router.workers_alive == 1
        # idempotent: a second failure report is a no-op
        assert router.fail_worker(victim_wid) == []
        assert router.workers_lost == 1


def test_submit_path_detects_dead_worker_and_fails_over():
    """A worker killed WITHOUT telling the router (chaos hook) is noticed
    by the next submit, evacuated, and the frame retried on the survivor."""
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(4)}
        for f in [router.submit(_frame(s), stream_id=s) for s in range(4)]:
            f.result()
        victim_wid = pins[0]
        router.kill_worker(victim_wid)  # router not told
        # submits to the dead pin fail over transparently
        for s in range(4):
            assert np.isfinite(
                np.asarray(router.submit(_frame(s), stream_id=s).result())
            ).all()
        assert router.is_dead(victim_wid)
        assert router.workers_lost == 1
        survivor_wid = next(
            w.wid for w in router.workers if w.wid != victim_wid
        )
        assert all(
            router.stream_worker(s) == survivor_wid for s in range(4)
        )


def test_watchdog_detects_silent_death():
    """The watchdog's poll (run synchronously here) notices a dead worker
    with no traffic flowing and triggers the same evacuation."""
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(4)}
        for f in [router.submit(_frame(s), stream_id=s) for s in range(4)]:
            f.result()
        victim_wid = pins[0]
        router.kill_worker(victim_wid)
        dog = FleetWatchdog(router, interval_s=60.0)  # won't tick on its own
        try:
            dog.poll()
        finally:
            dog.stop()
        assert router.is_dead(victim_wid)
        assert sorted(s for s, _, _ in router.rebalance_log) == sorted(
            s for s, w in pins.items() if w == victim_wid
        )


# --------------------------------------------------------- plan distribution
def test_mixed_plan_hash_rejected_at_construction():
    ctrl_a = _controller()
    ctrl_b = PlanController(
        cfg=BGConfig(r=8, sigma_s=4.0, sigma_r=60.0), height=H, width=W,
        streams_per_worker=4, temporal=True, sharded=False,
    )
    assert ctrl_a.plan_hash != ctrl_b.plan_hash
    w0 = LocalWorker(0, ctrl_a.payload())
    w1 = LocalWorker(1, ctrl_b.payload())
    try:
        with pytest.raises(PlanMismatch, match="mixed-plan"):
            FleetRouter(workers=[w0, w1], health_interval_s=None)
        # the controller's own verify refuses foreign workers too
        with pytest.raises(PlanMismatch):
            ctrl_a.verify([w0, w1])
    finally:
        w0.close(timeout=5.0)
        w1.close(timeout=5.0)


def test_worker_refuses_tampered_payload():
    payload = _controller().payload()
    payload["plan_hash"] = "0" * 16
    with pytest.raises(PlanMismatch, match="rebuilt plan hashes"):
        LocalWorker(0, payload)


def test_workers_share_one_compiled_executable():
    """Equal plans rebuilt from one payload share the jitted callable —
    plan distribution costs one compile, not N."""
    with _fleet(n_workers=3) as router:
        w0, w1, w2 = router.workers
        assert w0.plan == w1.plan == w2.plan
        assert w0.plan.executable() is w1.plan.executable()
        assert w1.plan.executable() is w2.plan.executable()


def test_controller_bless_roundtrip(tmp_path):
    """bless() writes the fleet's plan into a cache file that a later
    plan_for resolves from (provenance flips to the cache)."""
    path = str(tmp_path / "blessed.json")
    ctrl = _controller(streams_per_worker=4)
    key = ctrl.bless(path, measured_us=123.0)
    pc = PlanCache(path)
    ent = pc.lookup(key)
    assert ent is not None and ent["source"] == "controller"
    assert ent["plan_hash"] == ctrl.plan_hash
    resolved = plan_for(
        CFG, H, W, n_frames=4, temporal=True, sharded=False, cache=pc
    )
    assert resolved.plan_hash() == ctrl.plan_hash
    assert resolved.provenance.startswith("cache")


# ------------------------------------------------------------- backpressure
def test_router_sheds_before_worker_queue_overflows():
    """With a wedged worker, the router sheds at its own (small) bound with
    structured FleetSaturated; the worker's far larger request queue never
    fills, so submit can never wedge or raise raw queue.Full."""
    engine_max_queue = 64
    bound = 4
    inj = FaultInjector(FaultPlan(faults=(
        Fault(kind="hang_completion", delay_s=0.25, times=None),
    )))
    router = FleetRouter(
        controller=_controller(streams_per_worker=1),
        n_workers=1,
        max_worker_queue=bound,
        health_interval_s=None,
        worker_kwargs=dict(
            max_batch=1,
            batch_window_ms=0.0,
            max_queue=engine_max_queue,
            fault_injector=inj,
            engine_kwargs=dict(max_inflight=1),
        ),
    )
    try:
        router.open_stream(0, alpha=ALPHA)
        worker = router.workers[0]
        frame = _frame(7)
        accepted, shed = [], 0
        for _ in range(5 * bound):
            try:
                accepted.append(router.submit(frame, stream_id=0, block=False))
            except FleetSaturated as exc:
                shed += 1
                assert exc.wid == worker.wid
                assert exc.limit == bound and exc.depth >= bound
            # the worker's own queue stays far from its capacity: the
            # router's bound fired first every time
            assert worker.queue_depth() <= bound + 1 < engine_max_queue
        assert shed > 0 and router.router_shed == shed
        assert len(accepted) >= bound  # the bound's worth was accepted
        assert router.stats().router_shed == shed
        for f in accepted:
            assert np.isfinite(np.asarray(f.result(timeout=30.0))).all()
    finally:
        router.close()


# ---------------------------------------------------------------- telemetry
def test_engine_stats_merge_exact_percentiles():
    """Fleet percentiles come from the union of the latency reservoirs —
    exactly the single-engine estimator applied to the concatenation, not
    an average of per-engine percentiles."""
    a = EngineStats(
        submitted=10, completed=10, dispatches=5, queue_depth=1,
        inflight_depth=0, deadline_misses=1, mean_batch=2.0,
        latency_ms_p50=2.0, latency_ms_p99=4.0,
        latency_samples=(1.0, 2.0, 3.0, 4.0),
    )
    b = EngineStats(
        submitted=6, completed=6, dispatches=3, queue_depth=0,
        inflight_depth=2, deadline_misses=0, mean_batch=1.0,
        latency_ms_p50=100.0, latency_ms_p99=400.0, failed=2,
        carry_resets=3, latency_samples=(100.0, 200.0, 400.0),
    )
    m = EngineStats.merge([a, b, None])
    union = sorted(a.latency_samples + b.latency_samples)
    assert m.latency_samples == tuple(union)
    # same estimator as EngineStats.stats(): samples[min(int(q*n), n-1)]
    n = len(union)
    assert m.latency_ms_p50 == union[min(int(0.50 * n), n - 1)]
    assert m.latency_ms_p99 == union[min(int(0.99 * n), n - 1)]
    # the tail is dominated by the sick engine — never averaged away
    assert m.latency_ms_p99 == 400.0
    assert m.submitted == 16 and m.completed == 16 and m.failed == 2
    assert m.dispatches == 8 and m.deadline_misses == 1
    assert m.carry_resets == 3
    assert m.mean_batch == pytest.approx((2.0 * 5 + 1.0 * 3) / 8)
    # empty and sample-free fallbacks
    empty = EngineStats.merge([])
    assert empty.completed == 0 and empty.latency_ms_p99 == 0.0
    bare = EngineStats.merge([
        EngineStats(4, 4, 2, 0, 0, 0, 2.0, 10.0, 20.0),
        EngineStats(12, 12, 6, 0, 0, 0, 2.0, 30.0, 40.0),
    ])
    assert bare.latency_ms_p50 == pytest.approx((10 * 4 + 30 * 12) / 16)
    assert bare.latency_ms_p99 == pytest.approx((20 * 4 + 40 * 12) / 16)


def test_fleet_stats_rollup():
    with _fleet(n_workers=2) as router:
        for s in range(4):
            router.open_stream(s, alpha=ALPHA)
        for _ in range(3):
            for f in [router.submit(_frame(s), stream_id=s)
                      for s in range(4)]:
                f.result()
        st = router.stats()
        assert st.workers == 2 and st.workers_alive == 2
        assert st.streams == 4 and st.plan_hash == router.plan_hash
        assert st.merged.completed == 12
        assert st.merged.completed == sum(
            p.completed for p in st.per_worker
        )
        assert st.deadline_miss_rate == 0.0
        d = st.as_dict()
        assert d["merged_completed"] == 12
        assert "merged_latency_samples" not in d
        assert d["max_queue_depth"] == max(st.queue_depths)
