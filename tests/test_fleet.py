"""Fleet serving layer: affinity, plan distribution, failure containment,
backpressure, and telemetry rollup.

The load-bearing quartet:

  * ``test_affinity_invariant_1k_frames`` — across 1000 frames a warm
    stream's frames land on exactly one worker (``streams_served`` evidence
    on every worker) and the affinity table never silently moves.
  * ``test_worker_kill_quarantines_exactly_victim_streams`` — a worker
    death resets precisely its own streams' carries; survivors' carry
    objects are untouched (asserted by identity), and every migration in
    ``rebalance_log`` was preceded by a quarantine.
  * ``test_mixed_plan_hash_rejected_at_construction`` — a fleet whose
    workers disagree on ``plan_hash`` never comes up.
  * ``test_router_sheds_before_worker_queue_overflows`` — under a wedged
    worker the router's ``max_worker_queue`` bound fires (structured
    ``FleetSaturated``) while the worker's own request queue stays far
    from capacity.

Everything is scheduling-order independent: the watchdog thread is
disabled (``health_interval_s=None``) and failures are injected or
triggered synchronously.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import BGConfig
from repro.fleet import (
    FleetRouter,
    FleetSaturated,
    FleetWatchdog,
    LocalWorker,
    PlanController,
    PlanMismatch,
)
from repro.plan import plan_for
from repro.plan_cache import PlanCache
from repro.reliability import Fault, FaultInjector, FaultPlan
from repro.serving import EngineStats

CFG = BGConfig(r=4, sigma_s=4.0, sigma_r=60.0)
H, W = 24, 32
ALPHA = 0.6


def _controller(streams_per_worker=4, **kw):
    return PlanController(
        cfg=CFG, height=H, width=W,
        streams_per_worker=streams_per_worker, temporal=True,
        sharded=False, **kw,
    )


def _fleet(n_workers=2, **kw):
    kw.setdefault("health_interval_s", None)  # deterministic: no poller
    kw.setdefault("worker_kwargs", dict(max_batch=8, batch_window_ms=1.0))
    kw.setdefault("controller", _controller())
    return FleetRouter(n_workers=n_workers, **kw)


def _frame(seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 255.0, size=(H, W)).astype(np.float32)


# --------------------------------------------------------------- affinity
def test_affinity_invariant_1k_frames():
    """1000 frames over 8 warm streams on 2 workers: every stream's frames
    land on exactly the worker its affinity entry names, and nothing ever
    migrates (no failures -> empty rebalance_log)."""
    n_streams, rounds = 8, 125
    frames = [_frame(s) for s in range(n_streams)]
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(n_streams)}
        assert set(pins.values()) <= {0, 1}
        for t in range(rounds):
            futs = [
                router.submit(frames[s], stream_id=s)
                for s in range(n_streams)
            ]
            for f in futs:
                assert np.isfinite(np.asarray(f.result())).all()
            # the pin never moves while the stream is warm
            assert {s: router.stream_worker(s) for s in range(n_streams)} \
                == pins
        # per-worker accounting: each stream served by exactly its pin
        for s in range(n_streams):
            served_on = {
                w.wid for w in router.workers
                if w.streams_served.get(s, 0) > 0
            }
            assert served_on == {pins[s]}, (s, served_on, pins[s])
            assert router.workers[pins[s]].streams_served[s] == rounds
        assert router.rebalance_log == []
        assert router.rebalanced_streams == 0
        st = router.stats()
        assert st.merged.completed == n_streams * rounds
        assert st.merged.failed == 0


def test_temporal_fleet_requires_stream_id():
    with _fleet(n_workers=2) as router:
        with pytest.raises(ValueError, match="stream_id"):
            router.submit(_frame(0))
        with pytest.raises(KeyError):
            router.submit(_frame(0), stream_id="never-opened")


# ------------------------------------------------------ failure containment
def test_worker_kill_quarantines_exactly_victim_streams():
    """Killing one worker resets exactly its streams (cold restart on the
    survivor); surviving streams keep their carry objects untouched."""
    n_streams = 6
    frames = [_frame(100 + s) for s in range(n_streams)]
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(n_streams)}
        # warm every stream: two rounds so every carry is non-None
        for _ in range(2):
            for f in [router.submit(frames[s], stream_id=s)
                      for s in range(n_streams)]:
                f.result()
        victim_wid = pins[0]
        survivor = next(w for w in router.workers if w.wid != victim_wid)
        victims = sorted(s for s, w in pins.items() if w == victim_wid)
        keepers = sorted(s for s, w in pins.items() if w != victim_wid)
        assert victims and keepers, "rendezvous split both ways"
        kept_carries = {
            s: survivor.packer.sessions[s].carry for s in keepers
        }
        assert all(c is not None for c in kept_carries.values())

        moved = router.fail_worker(victim_wid)

        # exactly the victim's streams moved, each preceded by a quarantine
        assert sorted(s for s, _ in moved) == victims
        assert router.quarantined_streams == len(victims)
        assert router.rebalanced_streams == len(victims)
        assert sorted(s for s, _, _ in router.rebalance_log) == victims
        for s, old, new in router.rebalance_log:
            assert old == victim_wid and new == survivor.wid
        # victims restart cold on the survivor...
        for s in victims:
            assert router.stream_worker(s) == survivor.wid
            assert survivor.packer.sessions[s].carry is None
        # ...while survivors' carries are the very same objects
        for s in keepers:
            assert survivor.packer.sessions[s].carry is kept_carries[s]
        # the fleet still serves every stream
        for f in [router.submit(frames[s], stream_id=s)
                  for s in range(n_streams)]:
            assert np.isfinite(np.asarray(f.result())).all()
        assert router.workers_alive == 1
        # idempotent: a second failure report is a no-op
        assert router.fail_worker(victim_wid) == []
        assert router.workers_lost == 1


def test_submit_path_detects_dead_worker_and_fails_over():
    """A worker killed WITHOUT telling the router (chaos hook) is noticed
    by the next submit, evacuated, and the frame retried on the survivor."""
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(4)}
        for f in [router.submit(_frame(s), stream_id=s) for s in range(4)]:
            f.result()
        victim_wid = pins[0]
        router.kill_worker(victim_wid)  # router not told
        # submits to the dead pin fail over transparently
        for s in range(4):
            assert np.isfinite(
                np.asarray(router.submit(_frame(s), stream_id=s).result())
            ).all()
        assert router.is_dead(victim_wid)
        assert router.workers_lost == 1
        survivor_wid = next(
            w.wid for w in router.workers if w.wid != victim_wid
        )
        assert all(
            router.stream_worker(s) == survivor_wid for s in range(4)
        )


def test_watchdog_detects_silent_death():
    """The watchdog's poll (run synchronously here) notices a dead worker
    with no traffic flowing and triggers the same evacuation."""
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(4)}
        for f in [router.submit(_frame(s), stream_id=s) for s in range(4)]:
            f.result()
        victim_wid = pins[0]
        router.kill_worker(victim_wid)
        dog = FleetWatchdog(router, interval_s=60.0)  # won't tick on its own
        try:
            dog.poll()
        finally:
            dog.stop()
        assert router.is_dead(victim_wid)
        assert sorted(s for s, _, _ in router.rebalance_log) == sorted(
            s for s, w in pins.items() if w == victim_wid
        )


# --------------------------------------------------------- plan distribution
def test_mixed_plan_hash_rejected_at_construction():
    ctrl_a = _controller()
    ctrl_b = PlanController(
        cfg=BGConfig(r=8, sigma_s=4.0, sigma_r=60.0), height=H, width=W,
        streams_per_worker=4, temporal=True, sharded=False,
    )
    assert ctrl_a.plan_hash != ctrl_b.plan_hash
    w0 = LocalWorker(0, ctrl_a.payload())
    w1 = LocalWorker(1, ctrl_b.payload())
    try:
        with pytest.raises(PlanMismatch, match="mixed-plan"):
            FleetRouter(workers=[w0, w1], health_interval_s=None)
        # the controller's own verify refuses foreign workers too
        with pytest.raises(PlanMismatch):
            ctrl_a.verify([w0, w1])
    finally:
        w0.close(timeout=5.0)
        w1.close(timeout=5.0)


def test_worker_refuses_tampered_payload():
    payload = _controller().payload()
    payload["plan_hash"] = "0" * 16
    with pytest.raises(PlanMismatch, match="rebuilt plan hashes"):
        LocalWorker(0, payload)


def test_workers_share_one_compiled_executable():
    """Equal plans rebuilt from one payload share the jitted callable —
    plan distribution costs one compile, not N."""
    with _fleet(n_workers=3) as router:
        w0, w1, w2 = router.workers
        assert w0.plan == w1.plan == w2.plan
        assert w0.plan.executable() is w1.plan.executable()
        assert w1.plan.executable() is w2.plan.executable()


# ----------------------------------------- snapshot-restore failover (PR 9)
class _SnapshotTamperer(LocalWorker):
    """A snapshot-enabled LocalWorker whose snapshots can be doctored —
    the deterministic lever for the router's reject paths (staleness,
    foreign hash, poisoned carry) without a subprocess or a clock."""

    def __init__(self, *args, age_offset=0.0, hash_override=None,
                 poison=False, **kw):
        kw.setdefault("snapshots", True)
        super().__init__(*args, **kw)
        self.age_offset = age_offset
        self.hash_override = hash_override
        self.poison = poison

    def carry_snapshot(self, sid):
        import dataclasses

        snap = super().carry_snapshot(sid)
        if snap is None:
            return None
        if self.hash_override is not None:
            snap = dataclasses.replace(snap, plan_hash=self.hash_override)
        if self.poison:
            snap = dataclasses.replace(
                snap, carry=np.full_like(snap.carry, np.nan)
            )
        return dataclasses.replace(
            snap, taken_at=snap.taken_at - self.age_offset
        )


def _warmed_snapshot_fleet(worker_cls=None, n_streams=6, router_kw=None,
                           **worker_extra):
    """Two snapshot-enabled workers (worker 0 optionally a tamperer), all
    streams warmed twice. Returns (router, pins)."""
    payload = _controller().payload()
    kw = dict(max_batch=8, batch_window_ms=1.0, snapshots=True)
    w0 = (worker_cls or LocalWorker)(0, payload, **kw, **worker_extra)
    w1 = LocalWorker(1, payload, **kw)
    router = FleetRouter(workers=[w0, w1], health_interval_s=None,
                         **(router_kw or {}))
    pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(n_streams)}
    for _ in range(2):
        for f in [router.submit(_frame(200 + s), stream_id=s)
                  for s in range(n_streams)]:
            f.result()
    return router, pins


def test_fail_worker_restores_warm_carries_bit_exact():
    """With snapshots enabled, a worker death restores its warm streams'
    carries bit-for-bit onto the survivor — zero cold quarantines — and
    stays idempotent across the snapshot path."""
    router, pins = _warmed_snapshot_fleet()
    with router:
        victim_wid = pins[0]
        victim = router.workers[victim_wid]
        survivor = next(w for w in router.workers if w.wid != victim_wid)
        victims = sorted(s for s, w in pins.items() if w == victim_wid)
        assert victims, "rendezvous gave worker 0 no streams"
        want = {
            s: np.asarray(victim.packer.sessions[s].carry, np.float32)
            for s in victims
        }
        seen = {s: victim.packer.sessions[s].frames_seen for s in victims}

        moved = router.fail_worker(victim_wid)

        assert sorted(s for s, _ in moved) == victims
        assert router.restores == len(victims)
        assert router.quarantined_streams == 0  # warm restore, not cold
        assert router.rebalanced_streams == len(victims)
        assert len(router.restore_staleness_samples) == len(victims)
        assert all(a < 5.0 for a in router.restore_staleness_samples)
        for s in victims:
            sess = survivor.packer.sessions[s]
            np.testing.assert_array_equal(
                np.asarray(sess.carry, np.float32), want[s]
            )
            assert sess.frames_seen == seen[s]
        # restored streams keep serving — and the EMA continues, so the
        # next frame leaves the carry different from the restored state
        for s in victims:
            assert np.isfinite(np.asarray(
                router.submit(_frame(300 + s), stream_id=s).result()
            )).all()
        st = router.stats()
        assert st.restores == len(victims)
        assert st.quarantined_streams == 0
        assert st.restore_staleness_p99 >= 0.0
        # idempotent: the second report neither re-restores nor re-counts
        assert router.fail_worker(victim_wid) == []
        assert router.restores == len(victims)
        assert router.workers_lost == 1


def test_stale_snapshot_falls_back_to_cold_quarantine():
    """A snapshot older than restore_max_age_s is worse than a cold
    restart: the router must quarantine, not resurrect ancient state."""
    router, pins = _warmed_snapshot_fleet(
        worker_cls=_SnapshotTamperer, age_offset=60.0,
        router_kw=dict(restore_max_age_s=5.0),
    )
    with router:
        victims = sorted(s for s, w in pins.items() if w == 0)
        survivor = router.workers[1]
        router.fail_worker(0)
        assert router.restores == 0
        assert router.quarantined_streams == len(victims)
        for s in victims:
            assert survivor.packer.sessions[s].carry is None  # cold


def test_foreign_hash_snapshot_never_restored():
    """A snapshot stamped with a different plan hash is a carry from a
    different dispatch geometry — restoring it would silently corrupt the
    stream's EMA, so it must fall back to quarantine."""
    router, pins = _warmed_snapshot_fleet(
        worker_cls=_SnapshotTamperer, hash_override="f" * 16,
    )
    with router:
        victims = sorted(s for s, w in pins.items() if w == 0)
        router.fail_worker(0)
        assert router.restores == 0
        assert router.quarantined_streams == len(victims)


def test_failed_restore_is_all_or_nothing():
    """A snapshot that fails validation mid-restore (poisoned NaN carry)
    must leave the survivor's stream exactly as open_stream made it —
    cold, zero frames_seen — never half-restored."""
    router, pins = _warmed_snapshot_fleet(
        worker_cls=_SnapshotTamperer, poison=True,
    )
    with router:
        victims = sorted(s for s, w in pins.items() if w == 0)
        survivor = router.workers[1]
        router.fail_worker(0)
        assert router.restores == 0
        assert router.quarantined_streams == len(victims)
        for s in victims:
            sess = survivor.packer.sessions[s]
            assert sess.carry is None
            assert sess.frames_seen == 0
            assert sess.alpha == ALPHA
        # and the stream still serves (cold restart, finite output)
        for s in victims:
            assert np.isfinite(np.asarray(
                router.submit(_frame(400 + s), stream_id=s).result()
            )).all()


# ---------------------------------------------------------- rolling restart
def test_replace_worker_requires_death_and_matching_identity():
    with _fleet(n_workers=2) as router:
        with pytest.raises(ValueError, match="not dead"):
            router.replace_worker(0)
        with pytest.raises(KeyError):
            router.replace_worker("no-such-worker")
        router.fail_worker(0)
        fresh = router.replace_worker(0)
        assert fresh.wid == 0 and fresh.plan_hash == router.plan_hash
        assert router.worker_restarts == 1
        assert router.workers_alive == 2
        assert not router.is_dead(0)
        assert router.workers[0] is fresh


def test_replace_worker_returns_slot_to_rotation():
    """After replacement, new streams place onto the fresh slot by
    rendezvous; existing pins stay where failover put them."""
    with _fleet(n_workers=2) as router:
        pins = {s: router.open_stream(s, alpha=ALPHA) for s in range(6)}
        for f in [router.submit(_frame(s), stream_id=s) for s in range(6)]:
            f.result()
        router.fail_worker(0)
        router.replace_worker(0)
        # failover pins are sticky: nothing moved back
        for s in range(6):
            assert router.stream_worker(s) == 1
        # but new streams rendezvous over BOTH workers again
        new_pins = {
            s: router.open_stream(s, alpha=ALPHA) for s in range(6, 30)
        }
        assert set(new_pins.values()) == {0, 1}
        for s in list(new_pins) + list(pins):
            assert np.isfinite(np.asarray(
                router.submit(_frame(s), stream_id=s).result()
            )).all()


def test_replace_worker_rejects_wrong_wid_and_foreign_plan():
    payload = _controller().payload()
    with _fleet(n_workers=2) as router:
        router.fail_worker(1)
        wrong_wid = LocalWorker(99, payload)
        try:
            with pytest.raises(ValueError, match="does not match slot"):
                router.replace_worker(1, worker=wrong_wid)
        finally:
            wrong_wid.close(timeout=5.0)
        foreign = LocalWorker(1, PlanController(
            cfg=BGConfig(r=8, sigma_s=4.0, sigma_r=60.0), height=H, width=W,
            streams_per_worker=4, temporal=True, sharded=False,
        ).payload())
        try:
            with pytest.raises(PlanMismatch):
                router.replace_worker(1, worker=foreign)
        finally:
            foreign.close(timeout=5.0)
        assert router.worker_restarts == 0
        assert router.is_dead(1)  # the slot is still replaceable
        router.replace_worker(1)
        assert router.worker_restarts == 1


def test_explicit_workers_router_has_no_factory():
    payload = _controller().payload()
    w0 = LocalWorker(0, payload)
    w1 = LocalWorker(1, payload)
    router = FleetRouter(workers=[w0, w1], health_interval_s=None)
    with router:
        router.fail_worker(0)
        with pytest.raises(ValueError, match="factory"):
            router.replace_worker(0)
        # an explicit same-recipe replacement still works
        w0b = LocalWorker(0, payload)
        assert router.replace_worker(0, worker=w0b) is w0b


def test_controller_bless_roundtrip(tmp_path):
    """bless() writes the fleet's plan into a cache file that a later
    plan_for resolves from (provenance flips to the cache)."""
    path = str(tmp_path / "blessed.json")
    ctrl = _controller(streams_per_worker=4)
    key = ctrl.bless(path, measured_us=123.0)
    pc = PlanCache(path)
    ent = pc.lookup(key)
    assert ent is not None and ent["source"] == "controller"
    assert ent["plan_hash"] == ctrl.plan_hash
    resolved = plan_for(
        CFG, H, W, n_frames=4, temporal=True, sharded=False, cache=pc
    )
    assert resolved.plan_hash() == ctrl.plan_hash
    assert resolved.provenance.startswith("cache")


# ------------------------------------------------------------- backpressure
def test_router_sheds_before_worker_queue_overflows():
    """With a wedged worker, the router sheds at its own (small) bound with
    structured FleetSaturated; the worker's far larger request queue never
    fills, so submit can never wedge or raise raw queue.Full."""
    engine_max_queue = 64
    bound = 4
    inj = FaultInjector(FaultPlan(faults=(
        Fault(kind="hang_completion", delay_s=0.25, times=None),
    )))
    router = FleetRouter(
        controller=_controller(streams_per_worker=1),
        n_workers=1,
        max_worker_queue=bound,
        health_interval_s=None,
        worker_kwargs=dict(
            max_batch=1,
            batch_window_ms=0.0,
            max_queue=engine_max_queue,
            fault_injector=inj,
            engine_kwargs=dict(max_inflight=1),
        ),
    )
    try:
        router.open_stream(0, alpha=ALPHA)
        worker = router.workers[0]
        frame = _frame(7)
        accepted, shed = [], 0
        for _ in range(5 * bound):
            try:
                accepted.append(router.submit(frame, stream_id=0, block=False))
            except FleetSaturated as exc:
                shed += 1
                assert exc.wid == worker.wid
                assert exc.limit == bound and exc.depth >= bound
            # the worker's own queue stays far from its capacity: the
            # router's bound fired first every time
            assert worker.queue_depth() <= bound + 1 < engine_max_queue
        assert shed > 0 and router.router_shed == shed
        assert len(accepted) >= bound  # the bound's worth was accepted
        assert router.stats().router_shed == shed
        for f in accepted:
            assert np.isfinite(np.asarray(f.result(timeout=30.0))).all()
    finally:
        router.close()


# ---------------------------------------------------------------- telemetry
def test_engine_stats_merge_exact_percentiles():
    """Fleet percentiles come from the union of the latency reservoirs —
    exactly the single-engine estimator applied to the concatenation, not
    an average of per-engine percentiles."""
    a = EngineStats(
        submitted=10, completed=10, dispatches=5, queue_depth=1,
        inflight_depth=0, deadline_misses=1, mean_batch=2.0,
        latency_ms_p50=2.0, latency_ms_p99=4.0,
        latency_samples=(1.0, 2.0, 3.0, 4.0),
    )
    b = EngineStats(
        submitted=6, completed=6, dispatches=3, queue_depth=0,
        inflight_depth=2, deadline_misses=0, mean_batch=1.0,
        latency_ms_p50=100.0, latency_ms_p99=400.0, failed=2,
        carry_resets=3, latency_samples=(100.0, 200.0, 400.0),
    )
    m = EngineStats.merge([a, b, None])
    union = sorted(a.latency_samples + b.latency_samples)
    assert m.latency_samples == tuple(union)
    # same estimator as EngineStats.stats(): samples[min(int(q*n), n-1)]
    n = len(union)
    assert m.latency_ms_p50 == union[min(int(0.50 * n), n - 1)]
    assert m.latency_ms_p99 == union[min(int(0.99 * n), n - 1)]
    # the tail is dominated by the sick engine — never averaged away
    assert m.latency_ms_p99 == 400.0
    assert m.submitted == 16 and m.completed == 16 and m.failed == 2
    assert m.dispatches == 8 and m.deadline_misses == 1
    assert m.carry_resets == 3
    assert m.mean_batch == pytest.approx((2.0 * 5 + 1.0 * 3) / 8)
    # empty and sample-free fallbacks
    empty = EngineStats.merge([])
    assert empty.completed == 0 and empty.latency_ms_p99 == 0.0
    bare = EngineStats.merge([
        EngineStats(4, 4, 2, 0, 0, 0, 2.0, 10.0, 20.0),
        EngineStats(12, 12, 6, 0, 0, 0, 2.0, 30.0, 40.0),
    ])
    assert bare.latency_ms_p50 == pytest.approx((10 * 4 + 30 * 12) / 16)
    assert bare.latency_ms_p99 == pytest.approx((20 * 4 + 40 * 12) / 16)


def test_fleet_stats_rollup():
    with _fleet(n_workers=2) as router:
        for s in range(4):
            router.open_stream(s, alpha=ALPHA)
        for _ in range(3):
            for f in [router.submit(_frame(s), stream_id=s)
                      for s in range(4)]:
                f.result()
        st = router.stats()
        assert st.workers == 2 and st.workers_alive == 2
        assert st.streams == 4 and st.plan_hash == router.plan_hash
        assert st.merged.completed == 12
        assert st.merged.completed == sum(
            p.completed for p in st.per_worker
        )
        assert st.deadline_miss_rate == 0.0
        d = st.as_dict()
        assert d["merged_completed"] == 12
        assert "merged_latency_samples" not in d
        assert d["max_queue_depth"] == max(st.queue_depths)
