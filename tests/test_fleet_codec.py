"""Wire codec: seeded fuzz of the framing/validation contract.

The codec's docstring promises "a corrupt or adversarial peer can at worst
produce a CodecError, never code execution or an unbounded allocation".
These tests drive that promise with deterministic numpy-seeded fuzz (no
hypothesis dependency): random geometries and dtypes round-trip bit-exact;
truncation at EVERY byte boundary of a real message and random bit flips
anywhere in it decode to a structured ``CodecError`` (never a hang, never
a partial object); the length caps fire before allocation; and the array
re-validation in ``decode_array`` refuses geometry/dtype/byte-count
mismatches.
"""
import io
import json
import os
import struct
import sys
import zlib

import ml_dtypes  # ships with jax; the codec's bfloat16 wire name
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.fleet import CodecError, ConnectionClosed
from repro.fleet.codec import (
    MAX_HEADER_BYTES,
    MAX_PAYLOAD_BYTES,
    MSG_TYPES,
    PREAMBLE_BYTES,
    array_header,
    decode,
    decode_array,
    encode,
    read_message,
)

RNG = np.random.default_rng(0xB65)

WIRE_DTYPES = [
    np.float32, np.float64, np.float16, ml_dtypes.bfloat16,
    np.int8, np.int16, np.int32, np.int64,
    np.uint8, np.uint16, np.uint32, np.uint64,
    np.bool_,
]


def _random_array(rng):
    ndim = int(rng.integers(0, 5))
    shape = tuple(int(rng.integers(0, 6)) for _ in range(ndim))
    dtype = WIRE_DTYPES[int(rng.integers(0, len(WIRE_DTYPES)))]
    raw = rng.integers(0, 256, size=shape, dtype=np.uint8, endpoint=False)
    return raw.astype(dtype)


def _chunked_reader(data, chunk=7):
    """A recv(n) over a byte string that returns ragged chunks, then ''."""
    buf = io.BytesIO(data)
    return lambda n: buf.read(min(n, chunk))


# ------------------------------------------------------------- round trips
def test_roundtrip_fuzz_geometries_and_dtypes():
    """200 random (msg_type, header, array) messages survive encode ->
    decode and encode -> ragged-chunk read_message bit-exactly."""
    names = sorted(MSG_TYPES)
    for trial in range(200):
        rng = np.random.default_rng(1000 + trial)
        arr = _random_array(rng)
        name = names[int(rng.integers(0, len(names)))]
        header = dict(array_header(arr), rid=trial, sid=f"s{trial}")
        wire = encode(name, header, arr.tobytes())

        got_name, got_header, payload = decode(wire)
        assert got_name == name
        assert got_header == json.loads(json.dumps(header))
        out = decode_array(got_header, payload)
        assert out.dtype == arr.dtype and out.shape == arr.shape
        assert np.array_equal(out, arr)

        chunk = int(rng.integers(1, 11))
        got_name2, got_header2, payload2 = read_message(
            _chunked_reader(wire, chunk=chunk)
        )
        assert (got_name2, got_header2, payload2) == (
            got_name, got_header, payload
        )


def test_empty_payload_and_empty_header_roundtrip():
    name, header, payload = decode(encode("heartbeat", {}))
    assert name == "heartbeat" and header == {} and payload == b""


def test_unknown_message_type_refused_at_encode():
    with pytest.raises(CodecError, match="unknown message type"):
        encode("gossip", {})


# ------------------------------------------------- truncation: every cut
def test_truncation_at_every_byte_boundary_is_structured():
    """Cutting a real message at EVERY byte offset yields CodecError from
    decode() — except length 0, which read_message treats as a clean close
    (decode still refuses: its caller framed a partial buffer)."""
    arr = np.arange(24, dtype=np.float32).reshape(2, 3, 4)
    wire = encode("result", dict(array_header(arr), rid=1), arr.tobytes())
    for cut in range(len(wire)):
        with pytest.raises(CodecError):
            decode(wire[:cut])


def test_streamed_truncation_mid_message_vs_boundary():
    """read_message: EOF at a message boundary is ConnectionClosed (clean
    peer shutdown); EOF anywhere mid-message is CodecError (torn frame)."""
    arr = np.ones((3, 5), np.float32)
    wire = encode("submit", dict(array_header(arr), rid=7), arr.tobytes())
    with pytest.raises(ConnectionClosed):
        read_message(_chunked_reader(b""))
    rng = np.random.default_rng(2)
    cuts = {1, PREAMBLE_BYTES - 1, PREAMBLE_BYTES, len(wire) - 1} | {
        int(c) for c in rng.integers(1, len(wire), size=32)
    }
    for cut in cuts:
        with pytest.raises(CodecError, match="EOF|stalled"):
            read_message(_chunked_reader(wire[:cut]))


def test_idle_timeout_at_boundary_propagates_mid_message_does_not():
    """A TimeoutError before any byte is the caller's idle policy and
    propagates; a timeout after partial bytes is a torn frame."""
    def idle(n):
        raise TimeoutError("idle")

    with pytest.raises(TimeoutError):
        read_message(idle)

    wire = encode("ack", {"rid": 1})
    buf = io.BytesIO(wire[:4])

    def stall(n):
        chunk = buf.read(n)
        if not chunk:
            raise TimeoutError("stalled")
        return chunk

    with pytest.raises(CodecError, match="stalled mid-message"):
        read_message(stall)


# ----------------------------------------------------------- bit-flip fuzz
def test_bitflip_fuzz_never_yields_wrong_payload():
    """400 single-bit flips at random offsets anywhere in the wire bytes —
    preamble fields included — decode to CodecError, never to a wrong
    message (the CRC covers preamble[0:20]+header+payload, so even a flip
    that lands the type byte on another *valid* type cannot decode)."""
    arr = np.arange(60, dtype=np.int16).reshape(5, 12)
    wire = bytearray(
        encode("snapshot", dict(array_header(arr), sid=3), arr.tobytes())
    )
    rng = np.random.default_rng(3)
    for _ in range(400):
        i = int(rng.integers(0, len(wire)))
        bit = 1 << int(rng.integers(0, 8))
        flipped = bytes(wire[:i] + bytes([wire[i] ^ bit]) + wire[i + 1:])
        with pytest.raises(CodecError):
            decode(flipped)


@pytest.mark.parametrize("dtype", [np.float16, ml_dtypes.bfloat16])
def test_bitflip_fuzz_half_precision(dtype):
    """The 16-bit storage dtypes the bf16 carry/snapshot wire ships get the
    same single-bit-flip guarantee as the int16 message above."""
    arr = (np.arange(48, dtype=np.float32) / 7.0).reshape(4, 12).astype(dtype)
    wire = bytearray(
        encode("snapshot", dict(array_header(arr), sid=5), arr.tobytes())
    )
    rng = np.random.default_rng(0xBF16)
    for _ in range(200):
        i = int(rng.integers(0, len(wire)))
        bit = 1 << int(rng.integers(0, 8))
        flipped = bytes(wire[:i] + bytes([wire[i] ^ bit]) + wire[i + 1:])
        with pytest.raises(CodecError):
            decode(flipped)


def test_bad_magic_version_and_type_bytes():
    good = encode("hello", {"wid": 0})
    for i in (0, 4, 5):  # magic, version, message-type bytes
        bad = bytearray(good)
        bad[i] ^= 0xFF
        with pytest.raises(CodecError):
            decode(bytes(bad))


# ------------------------------------------------------------- length caps
def test_length_caps_fire_before_allocation():
    """A forged preamble claiming a 2**60-byte payload must be refused
    from the 24 preamble bytes alone — no read, no allocation."""
    pre = struct.Struct(">4sBBHIQI")
    forged = pre.pack(b"BGF1", 1, MSG_TYPES["submit"], 0, 10, 1 << 60, 0)
    with pytest.raises(CodecError, match="exceeds cap"):
        decode(forged)
    forged = pre.pack(
        b"BGF1", 1, MSG_TYPES["submit"], 0, MAX_HEADER_BYTES + 1, 0, 0
    )
    with pytest.raises(CodecError, match="exceeds cap"):
        decode(forged)

    calls = {"n": 0}

    def recv(n):
        calls["n"] += 1
        assert calls["n"] <= 1, "codec kept reading past a capped preamble"
        return pre.pack(b"BGF1", 1, 4, 0, 0, MAX_PAYLOAD_BYTES + 1, 0)

    with pytest.raises(CodecError, match="exceeds cap"):
        read_message(recv)


def test_oversize_refused_at_encode_too():
    with pytest.raises(CodecError, match="payload too large"):
        encode("submit", {}, b"\0" * (MAX_PAYLOAD_BYTES + 1))


# ------------------------------------------------------------- array layer
def test_array_header_refuses_object_dtype():
    with pytest.raises(CodecError, match="not allowed on the wire"):
        array_header(np.array([{"a": 1}], dtype=object))


def test_decode_array_revalidates_everything():
    arr = np.zeros((4, 6), np.float32)
    hdr, payload = array_header(arr), arr.tobytes()
    # geometry lies about the byte count
    with pytest.raises(CodecError, match="needs"):
        decode_array({"shape": [4, 7], "dtype": "<f4"}, payload)
    # dtype lies about the byte count
    with pytest.raises(CodecError, match="needs"):
        decode_array({"shape": [4, 6], "dtype": "<f8"}, payload)
    # smuggled object dtype in an otherwise-valid header
    with pytest.raises(CodecError, match="not allowed"):
        decode_array({"shape": [1], "dtype": "|O"}, payload)
    # negative dimension
    with pytest.raises(CodecError, match="negative"):
        decode_array({"shape": [-4, 6], "dtype": "<f4"}, payload)
    # missing fields / junk
    with pytest.raises(CodecError, match="bad array header"):
        decode_array({"dtype": "<f4"}, payload)
    with pytest.raises(CodecError, match="bad array header"):
        decode_array({"shape": [4, 6], "dtype": "not-a-dtype"}, payload)
    # the straight path still works and owns its memory (no frombuffer view
    # of a network buffer escapes)
    out = decode_array(hdr, payload)
    assert out.flags.owndata or out.base is None
    assert np.array_equal(out, arr)


def test_bfloat16_travels_by_name_not_void():
    """bfloat16's numpy ``.str`` is ``'<V2'`` (kind 'V'), which would decode
    as raw void — the codec ships it under the name ``"bfloat16"`` and must
    refuse the void spelling outright."""
    arr = np.asarray([1.5, -2.25, 65280.0], ml_dtypes.bfloat16)
    hdr = array_header(arr)
    assert hdr["dtype"] == "bfloat16"
    out = decode_array(hdr, arr.tobytes())
    assert out.dtype == arr.dtype and out.tobytes() == arr.tobytes()
    with pytest.raises(CodecError, match="not allowed"):
        decode_array({"shape": [3], "dtype": "<V2"}, arr.tobytes())
    with pytest.raises(CodecError, match="not allowed"):
        array_header(np.zeros(3, np.dtype("V2")))
    # non-finite bit patterns ride the snapshot wire bit-exact
    specials = np.asarray(
        [np.nan, np.inf, -np.inf, 0.0], np.float32
    ).astype(ml_dtypes.bfloat16)
    out2 = decode_array(array_header(specials), specials.tobytes())
    assert out2.tobytes() == specials.tobytes()
    # byte-count validation knows the 2-byte item size
    with pytest.raises(CodecError, match="needs"):
        decode_array({"shape": [4], "dtype": "bfloat16"}, arr.tobytes())


def test_decode_array_scalar_shape():
    arr = np.float64(3.25)
    out = decode_array(array_header(np.asarray(arr)), np.asarray(arr).tobytes())
    assert out.shape == () and float(out) == 3.25
