"""Reliability layer: fault injection, admission, retry/fallback, carry
quarantine, watchdog, shedding, and the PR-6 acceptance schedule.

The load-bearing pair is ``test_nan_frame_poisons_carry_without_guards`` /
``test_engine_quarantines_exactly_the_poisoned_streams``: the first proves
the failure mode *exists* (one NaN frame blended into the temporal EMA
corrupts every later frame of that stream — the guard-free packer serves
non-finite pixels forever), the second proves the engine's guarded path
detects it, fails exactly the corrupted requests with structured errors,
resets exactly the poisoned streams' carries, and serves those streams
clean again on the very next frame.

Wall-clock-sensitive tests carry ``@pytest.mark.timing`` (same contract as
tests/test_async_engine.py: budgets relax with host load, skip when the box
is oversubscribed). Everything else is scheduling-order independent —
fault injection is keyed on deterministic counters, and the engine tests
drive traffic round-synchronously so pack composition is exact.
"""
import os
import sys
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.core import BGConfig, add_gaussian_noise
from repro.data import synthetic_video
from repro.plan import BGPlan, plan_for, set_dispatch_hook
from repro.reliability import (
    AdmissionError,
    AllBackendsFailed,
    CircuitBreaker,
    DeadlineExceeded,
    EngineClosed,
    EngineTimeout,
    Fault,
    FaultInjector,
    FaultPlan,
    GuardedDispatch,
    InjectedFault,
    NonFiniteOutput,
    RetryPolicy,
    validate_frame,
)
from repro.serving import AsyncFrameEngine
from repro.video import MultiStreamPacker

from benchmarks.bench_bg_chaos import chaos_soak, default_fault_plan

CFG = BGConfig(r=4, sigma_s=4.0, sigma_r=60.0)

_TIMING_SKIP_LOAD = 4.0


def _timing_relax() -> float:
    """Same contract as tests/test_async_engine.py: budget multiplier from
    host load, skip on an oversubscribed box."""
    try:
        load = os.getloadavg()[0] / max(os.cpu_count() or 1, 1)
    except (AttributeError, OSError):
        return 1.0
    if load > _TIMING_SKIP_LOAD:
        pytest.skip(f"host oversubscribed (load/cpu = {load:.1f})")
    return max(1.0, load)


def _frames(n, h=32, w=48, seed=0):
    vid = synthetic_video(seed, n, h, w, motion=1.0)
    return [
        np.asarray(add_gaussian_noise(vid[t], 30.0, seed=seed + t))
        for t in range(n)
    ]


# --------------------------------------------------------------- fault layer
def test_fault_injection_is_deterministic():
    """Same plan + seed => bit-identical corruption and identical fire log,
    independent of wall-clock — the property that makes chaos runs replay."""
    plan = FaultPlan(
        faults=(
            Fault(kind="corrupt_frame", stream_id="a", frame_index=1,
                  fraction=0.25),
            Fault(kind="raise_dispatch", dispatch=2),
        ),
        seed=42,
    )
    frame = _frames(1)[0]

    def run_once():
        inj = FaultInjector(plan)
        out0 = inj.corrupt_frame(frame, "a")          # index 0: no match
        out1 = inj.corrupt_frame(frame, "a")          # index 1: corrupted
        clean_b = inj.corrupt_frame(frame, "b")       # wrong stream
        assert inj.on_dispatch("fused") == 0
        assert inj.on_dispatch("fused") == 1
        with pytest.raises(InjectedFault) as exc:
            inj.on_dispatch("fused")
        assert exc.value.dispatch == 2
        assert inj.on_dispatch("fused") == 3          # times=1: fired out
        return out0, out1, clean_b, list(inj.log)

    o0a, o1a, cba, loga = run_once()
    o0b, o1b, cbb, logb = run_once()
    np.testing.assert_array_equal(o0a, frame)          # untouched
    np.testing.assert_array_equal(cba, frame)
    assert np.isnan(o1a).any() and not np.isnan(frame).any()
    np.testing.assert_array_equal(o1a, o1b)            # seeded: replays
    assert loga == logb
    # fraction honored (one-pixel granularity)
    assert np.isnan(o1a).sum() == max(1, round(0.25 * frame.size))


def test_fault_validation():
    with pytest.raises(ValueError):
        Fault(kind="set_on_fire")
    with pytest.raises(ValueError):
        Fault(kind="corrupt_frame", mode="zeros")
    with pytest.raises(ValueError):
        Fault(kind="corrupt_frame", fraction=0.0)
    with pytest.raises(ValueError):
        Fault(kind="hang_completion", delay_s=-1.0)
    with pytest.raises(ValueError):
        Fault(kind="corrupt_frame", times=0)
    with pytest.raises(TypeError):
        FaultPlan(faults=("corrupt_frame",))


def test_carry_faults_and_plan_hook():
    """apply_carry_faults mutates exactly the matched streams' sessions; the
    plan_hook contextmanager fires on_dispatch from BGPlan.__call__ and
    restores the previous hook on exit."""
    packer = MultiStreamPacker(CFG)
    packer.open("w", alpha=0.6)
    packer.open("c", alpha=0.0)
    frames = _frames(2)
    packer.pack({"w": frames[0], "c": frames[0]})  # warm "w" (c stays cold)
    assert packer.sessions["w"].carry is not None

    inj = FaultInjector(
        FaultPlan(faults=(Fault(kind="corrupt_carry", stream_id="w",
                                mode="inf"),))
    )
    hit = inj.apply_carry_faults(packer.sessions)
    assert hit == ["w"]
    assert np.isinf(np.asarray(packer.sessions["w"].carry)).all()
    assert packer.sessions["c"].carry is None  # cold stream untouched

    # quarantine cures it: carry back to cold, counted once, idempotent
    assert packer.quarantine("w") is True
    assert packer.sessions["w"].carry is None
    assert packer.quarantine("w") is False
    assert packer.quarantine("nonexistent") is False
    assert packer.carry_resets == 1

    inj2 = FaultInjector(
        FaultPlan(faults=(Fault(kind="raise_dispatch", dispatch=0),))
    )
    plan = BGPlan(cfg=CFG, backend="reference")
    with inj2.plan_hook():
        with pytest.raises(InjectedFault):
            plan(jnp.stack([jnp.asarray(frames[0])]))
        plan(jnp.stack([jnp.asarray(frames[0])]))  # dispatch 1: serves
    assert set_dispatch_hook(None) is None  # hook restored after the block


# ----------------------------------------------------------------- admission
def test_admission_validation():
    frame = _frames(1)[0]
    assert validate_frame(frame).shape == frame.shape
    for bad in (
        np.full((8, 8), np.nan, np.float32),
        np.full((8, 8), np.inf, np.float32),
        np.zeros((8,), np.float32),          # not 2-D
        np.zeros((2, 2, 2), np.float32),     # not 2-D
        np.zeros((0, 8), np.float32),        # empty
        np.zeros((8, 8), np.complex64),      # complex
        np.array([["a", "b"], ["c", "d"]]),  # non-numeric
    ):
        with pytest.raises(AdmissionError):
            validate_frame(bad)
    # AdmissionError is a ValueError on purpose (legacy catch + fail-fast)
    with pytest.raises(ValueError):
        validate_frame(np.full((4, 4), np.nan, np.float32), stream_id="s")


def test_engine_rejects_bad_frames_at_submit():
    """A NaN frame never enters the pipeline: submit raises, nothing is
    queued, and the engine's counters don't move."""
    with AsyncFrameEngine(CFG, max_batch=4, batch_window_ms=5.0) as eng:
        with pytest.raises(AdmissionError):
            eng.submit(np.full((32, 48), np.nan, np.float32))
        st = eng.stats()
        assert st.submitted == 0 and st.failed == 0
        assert eng.flush(timeout=10.0)  # nothing outstanding
        out = eng.submit(_frames(1)[0]).result(timeout=60.0)
        assert np.isfinite(np.asarray(out)).all()


# ------------------------------------------------------------ retry/fallback
def test_fallback_ladder_derivation():
    streamed = plan_for(CFG, 32, 48, backend="fused_streamed", sharded=False)
    ladder = streamed.fallback_ladder()
    assert [p.backend for p in ladder] == [
        "fused_streamed", "fused", "reference",
    ]
    fused = plan_for(CFG, 32, 48, n_frames=4, temporal=True, sharded=False)
    assert [p.backend for p in fused.fallback_ladder()] == [
        "fused", "reference",
    ]
    assert all(p.temporal for p in fused.fallback_ladder())
    ref = BGPlan(cfg=CFG, backend="reference")
    assert ref.fallback_ladder() == (ref,)
    # the reference rung sheds mesh and tile (it shards neither)
    assert ladder[-1].mesh is None and ladder[-1].batch_tile is None


def test_retry_recovers_transient_failure():
    calls = []
    retries = []

    def flaky(plan):
        calls.append(plan)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "served"

    gd = GuardedDispatch(
        ["primary", "fallback"],
        RetryPolicy(max_attempts=3, backoff_s=0.0),
        on_retry=lambda: retries.append(1),
        sleep=lambda s: None,
    )
    result, rung = gd.call(flaky)
    assert (result, rung) == ("served", 0)  # recovered on the primary rung
    assert calls == ["primary"] * 3 and len(retries) == 2


def test_breaker_opens_and_ladder_falls_back():
    clock = {"t": 0.0}
    attempts = []
    fallbacks = []

    def broken_primary(plan):
        attempts.append(plan)
        if plan == "primary":
            raise RuntimeError("kernel backend down")
        return f"served by {plan}"

    gd = GuardedDispatch(
        ["primary", "fallback"],
        RetryPolicy(max_attempts=2, backoff_s=0.0, breaker_threshold=2,
                    breaker_cooldown_s=100.0),
        on_fallback=lambda: fallbacks.append(1),
        sleep=lambda s: None,
        clock=lambda: clock["t"],
    )
    # two dispatches exhaust the primary rung twice -> its breaker opens
    for _ in range(2):
        result, rung = gd.call(broken_primary)
        assert (result, rung) == ("served by fallback", 1)
    assert gd.breakers[0].open
    n_before = len(attempts)
    result, rung = gd.call(broken_primary)  # breaker open: skips primary
    assert rung == 1 and attempts[n_before:] == ["fallback"]
    assert len(fallbacks) == 3
    # after the cooldown, one half-open probe hits the primary again
    clock["t"] = 101.0
    gd.call(broken_primary)
    assert "primary" in attempts[n_before + 1:]


def test_last_rung_serves_even_when_open():
    gd = GuardedDispatch(
        ["only"],
        RetryPolicy(max_attempts=1, backoff_s=0.0, breaker_threshold=1,
                    breaker_cooldown_s=1000.0),
        sleep=lambda s: None,
    )
    with pytest.raises(AllBackendsFailed):
        gd.call(lambda p: (_ for _ in ()).throw(RuntimeError("down")))
    assert gd.breakers[0].open
    # degraded service beats refusing: the sole/last rung is still tried
    result, rung = gd.call(lambda p: "recovered")
    assert (result, rung) == ("recovered", 0)


def test_client_errors_fail_fast():
    attempts = []

    def buggy(plan):
        attempts.append(plan)
        raise KeyError("stream never opened")

    gd = GuardedDispatch(["a", "b"], RetryPolicy(backoff_s=0.0))
    with pytest.raises(KeyError):
        gd.call(buggy)
    assert attempts == ["a"]  # no retry, no downgrade — the bug surfaces


def test_all_backends_failed_carries_cause():
    gd = GuardedDispatch(
        ["a", "b"], RetryPolicy(max_attempts=2, backoff_s=0.0),
        sleep=lambda s: None,
    )
    boom = RuntimeError("persistent")
    with pytest.raises(AllBackendsFailed) as exc:
        gd.call(lambda p: (_ for _ in ()).throw(boom))
    assert exc.value.attempts == 4 and exc.value.rungs == 2
    assert exc.value.__cause__ is boom


def test_breaker_state_machine():
    clock = {"t": 0.0}
    br = CircuitBreaker(threshold=2, cooldown_s=10.0, clock=lambda: clock["t"])
    assert br.allow() and not br.open
    br.record_failure()
    assert br.allow()            # below threshold: still closed
    br.record_failure()
    assert br.open and not br.allow()
    clock["t"] = 10.0
    assert br.allow()            # half-open probe
    br.record_failure()          # probe failed: re-opens immediately
    assert br.open
    clock["t"] = 20.0
    assert br.allow()
    br.record_success()          # probe served: breaker closes fully
    assert not br.open and br.allow()


# ------------------------------------------- carry poisoning and quarantine
def test_nan_frame_poisons_carry_without_guards():
    """The pre-fix failure mode, demonstrated on the raw packer: one NaN
    frame blended into the temporal EMA contaminates the stream's carry, and
    every subsequent *clean* frame comes back non-finite — forever, because
    the EMA never forgets. ``quarantine`` is the cure: reset to cold, and
    the next clean frame serves finite again."""
    frames = _frames(6, seed=5)
    packer = MultiStreamPacker(CFG)
    packer.open("s", alpha=0.7)
    out = packer.pack({"s": frames[0]})["s"]
    assert np.isfinite(np.asarray(out)).all()

    nan_frame = frames[1].copy()
    nan_frame[3, 4] = np.nan  # a single bad pixel
    out = packer.pack({"s": nan_frame})["s"]
    assert not np.isfinite(np.asarray(out)).all()  # this frame is lost
    assert not np.isfinite(np.asarray(packer.sessions["s"].carry)).all()

    for t in (2, 3):  # clean frames, still poisoned via the carry
        out = packer.pack({"s": frames[t]})["s"]
        assert not np.isfinite(np.asarray(out)).all(), (
            "clean frame after the NaN came back finite — the EMA-poisoning "
            "premise of the quarantine machinery no longer holds"
        )

    assert packer.quarantine("s") is True  # the fix
    for t in (4, 5):
        out = packer.pack({"s": frames[t]})["s"]
        assert np.isfinite(np.asarray(out)).all()


def test_pack_guarded_flags():
    """The guard flags localize the poison: out_ok/carry_ok are per-row, in
    guard.order / guard.carry_sids, and only warm streams get carry flags."""
    frames = _frames(1)
    nan_frame = frames[0].copy()
    nan_frame[0, 0] = np.nan
    packer = MultiStreamPacker(CFG)
    packer.open("bad", alpha=0.6)
    packer.open("good", alpha=0.6)
    packer.open("cold", alpha=0.0)
    _, guard = packer.pack_guarded(
        {"bad": nan_frame, "good": frames[0], "cold": frames[0]}
    )
    order = list(guard.order)
    assert sorted(order) == order  # packs sort by repr
    out_ok = np.asarray(guard.out_ok)
    assert not out_ok[order.index("bad")]
    assert out_ok[order.index("good")] and out_ok[order.index("cold")]
    assert set(guard.carry_sids) == {"bad", "good"}  # cold has no carry
    carry_ok = np.asarray(guard.carry_ok)
    flags = dict(zip(guard.carry_sids, carry_ok))
    assert not flags["bad"] and flags["good"]

    # empty pack: a no-op guard
    results, guard = packer.pack_guarded({})
    assert results == {} and guard.out_ok is None and guard.carry_sids == ()


def test_engine_quarantines_exactly_the_poisoned_streams():
    """PR-6 acceptance, exact-count form: NaN frames injected on 2 of 8
    streams + one forced dispatch exception + one completion hang. Driven
    round-synchronously (each round's futures realized before the next is
    submitted) so pack composition is deterministic: every future resolves,
    exactly the corrupted requests fail (structured), exactly the two
    poisoned streams' carries reset, no non-finite frame is ever served,
    and the poisoned streams serve clean again on their next frame."""
    n_streams, rounds = 8, 5
    per_stream = {s: _frames(rounds, seed=100 + s) for s in range(n_streams)}
    packer = MultiStreamPacker(
        plan=plan_for(CFG, 32, 48, n_frames=n_streams, temporal=True)
    )
    for s in range(n_streams):
        packer.open(s, alpha=0.6)
    reset_sids = []
    orig_quarantine = packer.quarantine
    packer.quarantine = lambda sid: (
        reset_sids.append(sid), orig_quarantine(sid)
    )[1]

    inj = FaultInjector(default_fault_plan(n_streams, hang_delay_s=1.5))
    with AsyncFrameEngine(
        packer=packer, max_batch=n_streams, batch_window_ms=50.0,
        watchdog_ms=400.0,
    ) as eng:
        eng.fault_injector = inj
        outcomes = {}
        for t in range(rounds):
            futs = {
                s: eng.submit(per_stream[s][t], stream_id=s)
                for s in range(n_streams)
            }
            for s, f in futs.items():
                try:
                    out = np.asarray(f.result(timeout=120.0))
                    assert np.isfinite(out).all(), (
                        f"non-finite frame served as a success "
                        f"(stream {s}, round {t})"
                    )
                    outcomes[(s, t)] = "ok"
                except (NonFiniteOutput, EngineTimeout) as exc:
                    outcomes[(s, t)] = type(exc).__name__
        st = eng.stats()
        # engine still serves after the whole schedule
        post = eng.submit(per_stream[0][0], stream_id=0).result(timeout=120.0)
        assert np.isfinite(np.asarray(post)).all()

    # every submitted future resolved with a result or a structured error
    assert len(outcomes) == n_streams * rounds
    # the corrupted frames (stream 0 round 1, stream 1 round 2) failed with
    # NonFiniteOutput; every other request on other streams succeeded or —
    # for the hung pack — failed with EngineTimeout, never silently
    assert outcomes[(0, 1)] == "NonFiniteOutput"
    assert outcomes[(1, 2)] == "NonFiniteOutput"
    hung = [k for k, v in outcomes.items() if v == "EngineTimeout"]
    assert len(hung) in (0, n_streams)  # a trip fails its whole pack
    bad = {
        k for k, v in outcomes.items() if v == "NonFiniteOutput"
    } - {(0, 1), (1, 2)}
    assert not bad, f"clean requests failed the finite-guard: {bad}"
    # exactly the two poisoned streams' carries were reset, exactly once
    assert sorted(reset_sids) == [0, 1]
    assert packer.carry_resets == 2
    # the poisoned streams recovered within one frame: their next rounds
    # (3, 4) are "ok" unless eaten by the hung pack
    for s in (0, 1):
        later = [outcomes[(s, t)] for t in range(3, rounds)]
        assert all(v in ("ok", "EngineTimeout") for v in later)
        assert any(v == "ok" for v in later)
    # telemetry: the schedule was absorbed as retries/trips, not failures
    assert st.retries >= 1          # the injected dispatch exception
    assert st.watchdog_trips == 1   # the injected hang
    assert st.carry_resets == 2
    assert st.failed == len([v for v in outcomes.values() if v != "ok"])
    assert inj.fired == [1, 1, 1, 1]  # every scheduled fault actually fired


def test_engine_fallback_serves_when_kernel_backend_dies():
    """A persistently-failing primary backend downgrades to the reference
    rung instead of failing requests: backend-selective raise_dispatch
    faults (times=None) kill every 'fused' attempt; traffic still serves,
    counted as fallbacks."""
    frames = _frames(2)
    inj = FaultInjector(
        FaultPlan(
            faults=(Fault(kind="raise_dispatch", backend="fused",
                          times=None),)
        )
    )
    with AsyncFrameEngine(
        CFG, max_batch=2, batch_window_ms=5.0,
        retry_policy=RetryPolicy(max_attempts=2, backoff_s=0.0),
    ) as eng:
        eng.fault_injector = inj
        outs = [
            np.asarray(eng.submit(f).result(timeout=120.0)) for f in frames
        ]
        st = eng.stats()
    assert all(np.isfinite(o).all() for o in outs)
    assert st.fallbacks == 2 and st.completed == 2 and st.failed == 0
    assert st.retries >= 2  # the fused rung burned its attempts first


def test_engine_fallback_disabled_fails_requests():
    """fallback=False pins the primary backend: the same persistent fault
    now exhausts the ladder and fails the request with AllBackendsFailed
    (whose cause chain ends at the injected fault)."""
    inj = FaultInjector(
        FaultPlan(faults=(Fault(kind="raise_dispatch", times=None),))
    )
    with AsyncFrameEngine(
        CFG, max_batch=1, batch_window_ms=2.0, fallback=False,
        retry_policy=RetryPolicy(max_attempts=2, backoff_s=0.0),
    ) as eng:
        eng.fault_injector = inj
        fut = eng.submit(_frames(1)[0])
        with pytest.raises(AllBackendsFailed) as exc:
            fut.result(timeout=120.0)
        assert isinstance(exc.value.__cause__, InjectedFault)
        st = eng.stats()
    assert st.failed == 1 and st.completed == 0


# ------------------------------------------------- watchdog, shed, shutdown
@pytest.mark.timing
def test_watchdog_transient_hang_recovers_via_redispatch():
    """A stateless (non-video) batch whose completion hangs once is
    redispatched after the watchdog trips: the client gets a *result*, not
    an error — the trip shows only in telemetry."""
    relax = _timing_relax()
    frames = _frames(2)
    inj = FaultInjector(
        FaultPlan(
            faults=(Fault(kind="hang_completion", dispatch=1,
                          delay_s=2.0 * relax),)
        )
    )
    with AsyncFrameEngine(
        CFG, max_batch=1, batch_window_ms=2.0, watchdog_ms=400.0 * relax,
    ) as eng:
        eng.fault_injector = inj
        assert np.isfinite(
            np.asarray(eng.submit(frames[0]).result(timeout=120.0))
        ).all()  # dispatch 0: clean
        out = eng.submit(frames[1]).result(timeout=120.0)  # dispatch 1: hangs
        assert np.isfinite(np.asarray(out)).all()
        st = eng.stats()
    assert st.watchdog_trips == 1  # tripped, redispatched, served
    assert st.failed == 0 and st.completed == 2


@pytest.mark.timing
def test_watchdog_persistent_hang_fails_structurally():
    """Every completion hangs: the redispatch hangs too, the ladder
    exhausts, and the future fails with AllBackendsFailed whose cause is
    the watchdog's EngineTimeout — then the engine serves again once the
    hang clears."""
    relax = _timing_relax()
    frames = _frames(2)
    inj = FaultInjector(
        FaultPlan(
            faults=(Fault(kind="hang_completion", delay_s=1.5 * relax,
                          times=None),)
        )
    )
    with AsyncFrameEngine(
        CFG, max_batch=1, batch_window_ms=2.0, watchdog_ms=300.0 * relax,
        fallback=False,
        retry_policy=RetryPolicy(max_attempts=1, backoff_s=0.0),
    ) as eng:
        eng.fault_injector = inj
        fut = eng.submit(frames[0])
        with pytest.raises(AllBackendsFailed) as exc:
            fut.result(timeout=120.0)
        cause = exc.value.__cause__
        assert isinstance(cause, EngineTimeout)
        assert cause.timeout_s == pytest.approx(0.3 * relax)
        assert len(cause.uids) == 1
        eng.fault_injector = None  # hang clears: the engine outlives it
        out = eng.submit(frames[1]).result(timeout=120.0)
        assert np.isfinite(np.asarray(out)).all()
        st = eng.stats()
    assert st.watchdog_trips == 2  # original await + the redispatch await
    assert st.failed == 1 and st.completed == 1


def test_expired_deadline_is_shed():
    """A request whose deadline has already passed at collect time fails
    with DeadlineExceeded instead of being dispatched (a negative budget
    makes the expiry deterministic — no wall-clock race)."""
    frames = _frames(2)
    with AsyncFrameEngine(CFG, max_batch=4, batch_window_ms=2.0) as eng:
        fut = eng.submit(frames[0], deadline_ms=-1000.0)
        with pytest.raises(DeadlineExceeded) as exc:
            fut.result(timeout=60.0)
        assert exc.value.late_s >= 1.0
        out = eng.submit(frames[1]).result(timeout=60.0)  # engine unharmed
        assert np.isfinite(np.asarray(out)).all()
        st = eng.stats()
    assert st.shed == 1 and st.deadline_misses >= 1
    assert st.completed == 1 and st.dispatches == 1  # the shed never launched


@pytest.mark.timing
def test_close_joins_threads_even_with_full_queue():
    """The satellite-1 regression: close() on an engine whose request queue
    is still full used to bail on queue.Full without joining either thread,
    leaving queued futures pending forever. Now: close returns within its
    timeout, both threads die, and every queued future resolves (results
    for dispatched work, EngineClosed for work shed at shutdown)."""
    relax = _timing_relax()
    frames = _frames(1)
    # every completion sleeps, so the tiny queue stays full through close()
    inj = FaultInjector(
        FaultPlan(
            faults=(Fault(kind="hang_completion", delay_s=0.3 * relax,
                          times=None),)
        )
    )
    eng = AsyncFrameEngine(
        CFG, max_batch=1, max_queue=1, max_inflight=1, batch_window_ms=0.0
    )
    eng.fault_injector = inj
    futs = [eng.submit(frames[0], block=True, timeout=30.0) for _ in range(4)]
    t0 = time.monotonic()
    eng.close(timeout=0.2 * relax)  # shorter than the drain: flush times out
    # close is bounded even though work was still queued
    assert time.monotonic() - t0 < 15.0 * relax
    for t in (eng._dispatcher, eng._completer):
        t.join(timeout=30.0 * relax)
        assert not t.is_alive(), f"{t.name} leaked past close()"
    for f in futs:  # no future left pending
        assert f.done()
        exc = f.exception(timeout=10.0)
        assert exc is None or isinstance(exc, EngineClosed)
    # at least one request was still queued when close fired
    assert any(isinstance(f.exception(), EngineClosed) for f in futs)


def test_submit_after_close_raises_engine_closed():
    eng = AsyncFrameEngine(CFG, max_batch=1)
    eng.close()
    with pytest.raises(EngineClosed):  # an EngineClosed IS a RuntimeError
        eng.submit(_frames(1)[0])
    with pytest.raises(RuntimeError):
        eng.submit(_frames(1)[0])


# ------------------------------------------------------------ the full soak
@pytest.mark.timing
def test_chaos_soak_recovers_throughput():
    """The bench gate's assertion form: after the acceptance fault schedule,
    the same engine sustains >= 0.8x its clean-phase throughput, with every
    future resolved and zero silently-corrupted frames (reuses the
    benchmarks/bench_bg_chaos.py helper so test and CI gate measure the
    same thing)."""
    _timing_relax()
    # The correctness side (resolution, corruption, quarantine, watchdog
    # counters) must hold on every run; the recovery-throughput comparison
    # is a wall-clock measurement on a shared host, so a phase-sized GC or
    # scheduler pause can sink one soak — take the best ratio over two.
    best_ratio = 0.0
    for attempt in range(2):
        res = chaos_soak(rounds=4, watchdog_ms=600.0, hang_delay_s=2.0)
        assert res["all_resolved"], res
        assert res["corrupt_served"] == 0
        assert res["faulted_carry_resets"] >= 2  # both poisoned streams reset
        stats = res["stats"]
        assert stats.watchdog_trips == 1 and stats.retries >= 1
        best_ratio = max(best_ratio, res["fps_recovery"] / res["fps_clean"])
        if best_ratio >= 0.8:
            break
    assert best_ratio >= 0.8, (
        f"recovery ratio {best_ratio:.2f} < 0.8 across {attempt + 1} soak(s) "
        f"(last: recovery {res['fps_recovery']:.0f} fps vs clean "
        f"{res['fps_clean']:.0f} fps)"
    )
