"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept across shapes, radii, sigmas, and input dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BGConfig, add_gaussian_noise, synthetic_image
from repro.core.bilateral_grid import grid_normalize
from repro.kernels import (
    bg_blur,
    bg_create,
    bg_fused,
    bg_slice,
    bilateral_grid_filter_pallas,
)
from repro.kernels.ref import ref_blur, ref_create, ref_fused, ref_slice

SHAPES = [(32, 32), (61, 83), (96, 128), (45, 200)]
PARAMS = [
    (2, 2.0, 30.0),
    (4, 8.0, 70.0),
    (7, 4.0, 50.0),
    (12, 8.0, 70.0),
    (16, 8.0, 70.0),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _img(h, w, dtype=jnp.float32, seed=3):
    base = synthetic_image(h, w, seed=seed)
    noisy = add_gaussian_noise(base, 30.0, seed=seed + 1)
    return noisy.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("r,ss,sr", PARAMS)
def test_create_matches_ref(shape, r, ss, sr):
    cfg = BGConfig(r=r, sigma_s=ss, sigma_r=sr)
    img = _img(*shape)
    k = bg_create(img, cfg, interpret=True)
    ref = ref_create(img, cfg)
    assert k.shape == ref.shape
    np.testing.assert_allclose(np.asarray(k), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("shape", SHAPES[:2])
@pytest.mark.parametrize("r,ss,sr", PARAMS)
def test_blur_matches_ref(shape, r, ss, sr):
    cfg = BGConfig(r=r, sigma_s=ss, sigma_r=sr)
    grid = ref_create(_img(*shape), cfg)
    k = bg_blur(grid, cfg, interpret=True)
    ref = ref_blur(grid, cfg)
    np.testing.assert_allclose(np.asarray(k), np.asarray(ref), rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("shape", SHAPES[:2])
@pytest.mark.parametrize("r,ss,sr", PARAMS)
def test_slice_matches_ref(shape, r, ss, sr):
    cfg = BGConfig(r=r, sigma_s=ss, sigma_r=sr)
    img = _img(*shape)
    gf = grid_normalize(ref_blur(ref_create(img, cfg), cfg))
    k = bg_slice(gf, img, cfg, interpret=True)
    ref = ref_slice(gf, img, cfg)
    np.testing.assert_allclose(np.asarray(k), np.asarray(ref), atol=1e-3)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("r,ss,sr", PARAMS)
def test_fused_matches_ref(shape, r, ss, sr):
    cfg = BGConfig(r=r, sigma_s=ss, sigma_r=sr)
    img = _img(*shape)
    k = bg_fused(img, cfg, interpret=True)
    ref = ref_fused(img, cfg)
    np.testing.assert_allclose(np.asarray(k), np.asarray(ref), atol=5e-3)


@pytest.mark.parametrize("dtype", DTYPES)
def test_dtype_sweep_full_pipeline(dtype):
    """bf16 inputs are upcast internally; quantized outputs must agree with
    the float32 path within 1 intensity level."""
    cfg = BGConfig(r=7, sigma_s=4.0, sigma_r=50.0)
    img32 = _img(61, 83, jnp.float32)
    img = img32.astype(dtype)
    out = bilateral_grid_filter_pallas(img, cfg, interpret=True)
    ref = bilateral_grid_filter_pallas(img32, cfg, interpret=True)
    diff = np.abs(np.asarray(out) - np.asarray(ref))
    assert np.mean(diff <= 1.0) > 0.99


@pytest.mark.parametrize("fused", [True, False])
def test_pipeline_wrapper_matches_core(fused):
    from repro.core import bilateral_grid_filter

    cfg = BGConfig(r=7, sigma_s=4.0, sigma_r=50.0)
    img = _img(61, 83)
    k = bilateral_grid_filter_pallas(img, cfg, fused=fused, interpret=True)
    ref = bilateral_grid_filter(img, cfg)
    diff = np.abs(np.asarray(k) - np.asarray(ref))
    # float-accumulation order differs; quantized outputs may flip 1 LSB rarely
    assert np.mean(diff == 0.0) > 0.995
    assert diff.max() <= 1.0


def test_pow2_weight_mode_kernels():
    cfg = BGConfig(r=8, sigma_s=8.0, sigma_r=70.0, weight_mode="pow2")
    img = _img(48, 64)
    k = bg_fused(img, cfg, interpret=True)
    ref = ref_fused(img, cfg)
    np.testing.assert_allclose(np.asarray(k), np.asarray(ref), atol=5e-3)


def test_kernel_grid_layout_roundtrip():
    """bg_create output layout must be identical to the core grid layout."""
    cfg = BGConfig(r=5, sigma_s=3.0, sigma_r=40.0)
    img = _img(40, 55)
    k = bg_create(img, cfg, interpret=True)
    assert float(jnp.sum(k[..., 0])) == 40 * 55
