"""Batch-axis sharded service path: bit-equivalence with the single-device
fused kernel, for every (batch size, device count) shape class.

Each test runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps its single-device view (per the harness contract, same pattern
as test_distributed.py). Sharding must be numerically invisible:

  * ragged batches (b not divisible by the device count),
  * b < n_devices (idle devices denoising pure padding),
  * b == 1 (a mesh of mostly-idle devices),
  * mesh=None auto-mesh over all devices,
  * sharded + stream_input composition,

all bit-identical to ``bg_fused_kernel_call`` on the same batch.
"""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 420) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_bit_identical_to_single_device():
    """Every (b, ndev) shape class, incl. ragged, b < ndev, b == 1."""
    run_sub(
        """
        import jax, numpy as np
        from repro.core import BGConfig, add_gaussian_noise, synthetic_batch
        from repro.kernels import bg_fused
        from repro.sharding.bg_shard import batch_mesh, bg_denoise_sharded

        assert jax.device_count() == 8
        cfg = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)
        # ragged frame shape too (h % r != 0, w % r != 0)
        h, w = 45, 55
        for b, nd in [(8, 8), (5, 4), (6, 8), (3, 8), (1, 8), (1, 1), (7, 2)]:
            imgs = add_gaussian_noise(
                synthetic_batch(b, h, w, seed=b), 30.0, seed=b + 50)
            ref = bg_fused(imgs, cfg, interpret=True)
            out = bg_denoise_sharded(
                imgs, cfg, mesh=batch_mesh(nd), interpret=True)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
            print(f"OK b={b} nd={nd}")

        # mesh=None: auto-mesh over all local devices
        imgs = add_gaussian_noise(synthetic_batch(5, h, w, seed=0), 30.0, seed=9)
        np.testing.assert_array_equal(
            np.asarray(bg_denoise_sharded(imgs, cfg, interpret=True)),
            np.asarray(bg_fused(imgs, cfg, interpret=True)))
        print("OK auto-mesh")

        # sharded + double-buffered input stream composition
        np.testing.assert_array_equal(
            np.asarray(bg_denoise_sharded(
                imgs, cfg, mesh=batch_mesh(4), interpret=True,
                stream_input=True)),
            np.asarray(bg_fused(imgs, cfg, interpret=True)))
        print("OK sharded+stream_input")
        """
    )


def test_single_device_fallback_is_plain_call():
    """On a 1-device host mesh=None must degrade to the unsharded kernel."""
    run_sub(
        """
        import jax, numpy as np
        from repro.core import BGConfig, add_gaussian_noise, synthetic_batch
        from repro.kernels import bg_fused
        from repro.sharding.bg_shard import bg_denoise_sharded

        assert jax.device_count() == 1
        cfg = BGConfig(r=7, sigma_s=4.0, sigma_r=50.0)
        imgs = add_gaussian_noise(synthetic_batch(3, 41, 60, seed=2), 30.0, seed=3)
        np.testing.assert_array_equal(
            np.asarray(bg_denoise_sharded(imgs, cfg, interpret=True)),
            np.asarray(bg_fused(imgs, cfg, interpret=True)))
        # single (h, w) frame squeeze path
        np.testing.assert_array_equal(
            np.asarray(bg_denoise_sharded(imgs[0], cfg, interpret=True)),
            np.asarray(bg_fused(imgs[0], cfg, interpret=True)))
        print("OK fallback")
        """,
        devices=1,
    )


def test_frame_engine_micro_batches_mesh_divisible():
    """The serving engine only dispatches mesh-divisible micro-batches (tail
    flush excepted) and returns bit-exact per-frame results."""
    run_sub(
        """
        import jax, numpy as np
        from repro.core import BGConfig, add_gaussian_noise, synthetic_batch
        from repro.data.pipeline import denoise_batch
        from repro.serving import FrameDenoiseEngine, FrameRequest

        assert jax.device_count() == 8
        cfg = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)
        frames = add_gaussian_noise(
            synthetic_batch(11, 40, 48, seed=4), 30.0, seed=5)
        ref = denoise_batch(frames, cfg, use_kernels=True)

        eng = FrameDenoiseEngine(cfg, max_batch=8)
        assert eng.n_devices == 8 and eng.max_batch == 8
        done = []
        for i in range(11):
            eng.submit(FrameRequest(uid=i, frame=frames[i]))
            batch = eng.step()
            if batch:  # fires exactly once the 8th frame arrives
                assert len(batch) % eng.n_devices == 0
            done.extend(batch)
        assert len(done) == 8 and eng.pending() == 3
        done.extend(eng.flush())  # ragged tail: forced, padded internally
        assert len(done) == 11 and eng.pending() == 0
        for r in done:
            np.testing.assert_array_equal(
                np.asarray(r.result), np.asarray(ref[r.uid]))
        print("OK frame engine")
        """
    )


def test_sharded_dispatch_through_pipeline_and_streaming():
    """denoise_batch(sharded=True) and the streaming scan's sharded wrapper
    agree with their single-device equivalents on a multi-device host."""
    run_sub(
        """
        import jax, numpy as np
        from repro.core import (BGConfig, add_gaussian_noise,
                                bilateral_grid_filter_streaming, synthetic_batch)
        from repro.data.pipeline import denoise_batch
        from repro.sharding.bg_shard import batch_mesh

        assert jax.device_count() == 8
        cfg = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)
        imgs = add_gaussian_noise(synthetic_batch(5, 40, 55, seed=6), 30.0, seed=7)

        np.testing.assert_array_equal(
            np.asarray(denoise_batch(imgs, cfg, sharded=True)),
            np.asarray(denoise_batch(imgs, cfg, use_kernels=True)))

        out = bilateral_grid_filter_streaming(
            imgs, cfg, sharded=True, mesh=batch_mesh(4))
        ref = bilateral_grid_filter_streaming(imgs, cfg)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5)
        print("OK pipeline+streaming sharded")
        """
    )
