"""Optimizer / train_step / checkpoint / trainer fault-tolerance tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.configs.registry import get_smoke_config
from repro.data import lm_batches
from repro.models import init_params
from repro.train import OptConfig, Trainer, make_train_step
from repro.train.optimizer import adamw_init, adamw_update, global_norm, lr_at_step
from repro.train.train_step import init_train_state


# ------------------------------------------------------------- optimizer
def test_adamw_matches_numpy_reference():
    """One AdamW step vs a hand-rolled numpy implementation."""
    cfg = OptConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    grad_clip=1e9, warmup_steps=1, decay_steps=10)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]])}
    st = adamw_init(p)
    new_p, st2, _ = adamw_update(g, st, p, cfg)

    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.01 * gn * gn
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    lr = 1e-2 * 1 / 1  # step 1 of warmup 1
    expect = np.asarray(p["w"]) - lr * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=110, min_lr_ratio=0.1)
    lrs = [float(lr_at_step(cfg, jnp.asarray(s))) for s in (0, 5, 10, 60, 110, 500)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


def test_grad_clip_applied():
    cfg = OptConfig(lr=1.0, grad_clip=0.1, warmup_steps=1, decay_steps=2,
                    weight_decay=0.0, min_lr_ratio=1.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st = adamw_init(p)
    _, _, metrics = adamw_update(g, st, p, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


# ----------------------------------------------------- grad accumulation
def test_grad_accum_equivalence():
    """accum=2 over batch 8 must equal accum=1 on the same batch."""
    import dataclasses

    cfg1 = get_smoke_config("yi-6b")
    cfg2 = dataclasses.replace(cfg1, grad_accum=2)
    opt = OptConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
    params = init_params(jax.random.PRNGKey(0), cfg1)
    opt_state = init_train_state(params)
    batch = next(lm_batches(cfg1.vocab_size, 8, 16, 1, seed=0))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    p1, _, m1 = make_train_step(cfg1, opt)(params, opt_state, batch)
    p2, _, m2 = make_train_step(cfg2, opt)(params, init_train_state(params), batch)
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    # grads agree to fp32 summation-order noise; Adam's rsqrt(v) at step 1
    # (v ~ g^2, bias-corrected) amplifies that noise into the update by up to
    # ~lr * rel_err, so the post-step param tolerance is lr-scaled.
    assert d < 1e-3, d
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)


# ------------------------------------------------------------ checkpoints
def test_checkpoint_roundtrip_and_atomicity():
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck")
        save_pytree(path, tree, {"step": 7})
        like = jax.eval_shape(lambda: tree)
        out = load_pytree(path, like)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_manager_retention_and_latest():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, retention=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"x": jnp.asarray([s])})
        assert mgr.steps() == [3, 4]
        assert mgr.latest_step() == 4
        out, meta = mgr.restore({"x": jnp.asarray([0])})
        assert int(out["x"][0]) == 4 and meta["step"] == 4


def test_trainer_resume_continues_step_count():
    cfg = get_smoke_config("stablelm-1.6b")
    opt = OptConfig(lr=1e-3, warmup_steps=2, decay_steps=50)
    with tempfile.TemporaryDirectory() as d:
        t1 = Trainer(cfg, opt, d, ckpt_every=3)
        assert t1.init_or_resume() == "initialized"
        t1.run(lm_batches(cfg.vocab_size, 4, 16, 5, seed=1), max_steps=5)
        t2 = Trainer(cfg, opt, d, ckpt_every=3)
        assert t2.init_or_resume() == "resumed"
        assert t2.step == 5
        # heartbeat file exists and parses
        import json

        hb = json.load(open(os.path.join(d, "heartbeat.json")))
        assert hb["step"] == 5


def test_grad_compress_roundtrip_error_bound():
    from repro.train.grad_compress import quantize_dequantize_roundtrip

    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    y = quantize_dequantize_roundtrip(x)
    rel = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
    assert rel < 1.0 / 127.0 + 1e-6
