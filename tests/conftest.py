"""Suite-wide fixtures.

Plan-cache hermeticity: ``repro.plan.plan_for`` consults the persistent
measured-plan cache (``~/.cache/repro/bg_plan_cache.json`` or
``$REPRO_PLAN_CACHE``) before the roofline model. Tests assert the *model's*
picks, so an ambient cache left by a ``bench_plan_sweep`` run on the
developer's machine must not leak into them — every test session gets its
own empty cache file unless a test points elsewhere itself.
"""
import pytest


@pytest.fixture(autouse=True)
def _isolated_plan_cache(tmp_path_factory, monkeypatch):
    path = tmp_path_factory.getbasetemp() / "plan_cache.json"
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(path))
    yield
