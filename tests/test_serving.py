"""Serving engine: continuous batching == sequential decode; slot reuse."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import forward, init_caches, init_params
from repro.serving import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("yi-6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _standalone_greedy(cfg, params, prompt, n, max_len=64):
    caches = init_caches(cfg, 1, max_len)
    lp, caches, _ = forward(
        params, cfg, tokens=jnp.asarray([prompt], jnp.int32), mode="prefill",
        caches=caches,
    )
    out = [int(jnp.argmax(lp[:, -1], -1)[0])]
    pos = len(prompt)
    for _ in range(n - 1):
        t = jnp.asarray([[out[-1]]], jnp.int32)
        ld, caches, _ = forward(
            params, cfg, tokens=t, positions=jnp.asarray([[pos]], jnp.int32),
            mode="decode", caches=caches,
        )
        out.append(int(jnp.argmax(ld[:, 0], -1)[0]))
        pos += 1
    return out


def test_batched_matches_sequential(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_slots=3, max_len=64)
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5, 5, 5, 5, 5]]
    reqs = [Request(uid=i, prompt=p, max_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        assert eng.submit(r)
    eng.run_to_completion()
    for r, p in zip(reqs, prompts):
        assert r.generated == _standalone_greedy(cfg, params, p, 6)


def test_slot_reuse_and_admission(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, max_slots=2, max_len=64)
    r0 = Request(uid=0, prompt=[1, 2], max_tokens=3)
    r1 = Request(uid=1, prompt=[3, 4], max_tokens=3)
    r2 = Request(uid=2, prompt=[5, 6], max_tokens=3)
    assert eng.submit(r0) and eng.submit(r1)
    assert not eng.submit(r2)  # full
    assert not eng.submit(r0)  # duplicate uid rejected
    eng.run_to_completion()
    assert r0.done and r1.done
    assert eng.submit(r2)  # freed slot accepts new request
    eng.run_to_completion()
    assert r2.generated == _standalone_greedy(cfg, params, [5, 6], 3)


def test_eos_stops_early(setup):
    cfg, params = setup
    first = _standalone_greedy(cfg, params, [1, 2, 3, 4], 1)[0]
    eng = ServeEngine(cfg, params, max_slots=1, max_len=64)
    r = Request(uid=0, prompt=[1, 2, 3, 4], max_tokens=50, eos_id=first)
    eng.submit(r)
    # first generated token == eos -> engine must stop at the next step check
    eng.run_to_completion()
    assert r.done and len(r.generated) <= 3
