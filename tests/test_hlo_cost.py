"""Structural HLO cost model: trip-count multiplication, dot FLOPs,
slice/DUS refinement, collective classification — validated against
hand-computable programs compiled on the host backend."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze_hlo


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    N, D, T = 64, 64, 7

    def f(c, xs):
        def body(c, x):
            return jnp.tanh(c @ x), ()

        c, _ = jax.lax.scan(body, c, xs)
        return c

    txt = _hlo(
        f,
        jax.ShapeDtypeStruct((N, D), jnp.float32),
        jax.ShapeDtypeStruct((T, D, D), jnp.float32),
    )
    hc = analyze_hlo(txt)
    expect = T * 2 * N * D * D
    assert hc.flops == pytest.approx(expect, rel=0.01), (hc.flops, expect)


def test_nested_scan_multiplies():
    D, T1, T2 = 32, 3, 5

    def f(c):
        def outer(c, _):
            def inner(c, _):
                return c @ c, ()

            c, _ = jax.lax.scan(inner, c, None, length=T2)
            return c, ()

        c, _ = jax.lax.scan(outer, c, None, length=T1)
        return c

    hc = analyze_hlo(_hlo(f, jax.ShapeDtypeStruct((D, D), jnp.float32)))
    expect = T1 * T2 * 2 * D**3
    assert hc.flops == pytest.approx(expect, rel=0.01)


def test_dynamic_slice_in_loop_counts_slice_not_operand():
    """A scan that slices one row per step must charge ~row bytes per step,
    not the whole array."""
    S, D = 1024, 256

    def f(xs):
        def body(acc, i):
            row = jax.lax.dynamic_slice(xs, (i, 0), (1, D))
            return acc + jnp.sum(row), ()

        acc, _ = jax.lax.scan(body, 0.0, jnp.arange(S))
        return acc

    hc = analyze_hlo(_hlo(f, jax.ShapeDtypeStruct((S, D), jnp.float32)))
    full_per_step = S * (S * D * 4)  # what naive counting would charge
    assert hc.hbm_bytes < full_per_step / 20, (hc.hbm_bytes, full_per_step)


def test_dot_flops_with_batch_dims():
    B, M, K, N = 4, 32, 48, 16

    def f(a, b):
        return jnp.einsum("bmk,bkn->bmn", a, b)

    hc = analyze_hlo(
        _hlo(
            f,
            jax.ShapeDtypeStruct((B, M, K), jnp.float32),
            jax.ShapeDtypeStruct((B, K, N), jnp.float32),
        )
    )
    assert hc.flops == pytest.approx(2 * B * M * K * N, rel=0.01)


def test_collectives_counted_with_ring_model():
    import subprocess, sys, os, textwrap

    code = textwrap.dedent(
        """
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import sys
        sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_cost import analyze_hlo
        from repro.sharding.compat import set_mesh

        mesh = jax.make_mesh((8,), ("x",))
        sh = NamedSharding(mesh, P("x", None))
        rep = NamedSharding(mesh, P())
        def f(a):
            return jnp.sum(a * 2.0)
        with set_mesh(mesh):
            txt = jax.jit(f, in_shardings=(sh,), out_shardings=rep).lower(
                jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile().as_text()
        hc = analyze_hlo(txt)
        kinds = set(hc.collectives)
        assert kinds & {"all-reduce", "all-reduce->rs"}, kinds
        print("OK", hc.collectives)
        """
        % (os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"),)
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=300
    )
    assert out.returncode == 0, out.stderr[-2000:]


def test_roofline_report_renders():
    from repro.launch.roofline import render_dryrun_table, render_roofline_table

    cells = [
        {
            "arch": "a",
            "shape": "train_4k",
            "mesh": "16x16",
            "status": "ok",
            "compile_s": 1.0,
            "memory": {"argument_size_in_bytes": 1, "temp_size_in_bytes": 2,
                       "output_size_in_bytes": 3},
            "useful_flops_ratio": 0.7,
            "roofline": {
                "compute_s": 1.0,
                "memory_s": 2.0,
                "collective_s": 0.5,
                "dominant": "memory",
                "collective_breakdown": {"all-gather": {"count": 3, "bytes": 9.0}},
            },
        },
        {"arch": "b", "shape": "long_500k", "mesh": "16x16",
         "status": "skipped", "reason": "encoder-only"},
    ]
    t1 = render_dryrun_table(cells)
    t2 = render_roofline_table(cells)
    assert "SKIP" in t1 and "all-gather×3" in t1
    assert "**memory**" in t2 and "50.0%" in t2
