"""Distributed-mechanics tests. Each test runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest process
keeps its single-device view (per the harness contract)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 420) -> str:
    code = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_smoke_config
        from repro.launch.dryrun import _shard_tree
        from repro.models import init_params, param_logical_axes
        from repro.sharding.partitioning import DEFAULT_RULES, axis_rules
        from repro.sharding.compat import set_mesh
        from repro.train import OptConfig, make_train_step
        from repro.train.train_step import init_train_state
        from repro.data import lm_batches

        cfg = get_smoke_config("yi-6b")
        opt = OptConfig(lr=1e-3, warmup_steps=1, decay_steps=10)
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_train_state(params)
        batch = next(lm_batches(cfg.vocab_size, 8, 16, 1, seed=0))
        batch = {k: jnp.asarray(v) for k, v in batch.items()}

        # single-device reference
        p_ref, _, m_ref = jax.jit(make_train_step(cfg, opt))(params, state, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p_sh = _shard_tree(param_logical_axes(cfg), mesh, DEFAULT_RULES,
                           jax.eval_shape(lambda: params))
        with axis_rules(DEFAULT_RULES), set_mesh(mesh):
            params_d = jax.tree.map(lambda x, s: jax.device_put(x, s), params, p_sh)
            state_d = {"m": jax.tree.map(lambda x, s: jax.device_put(x, s), state["m"], p_sh),
                       "v": jax.tree.map(lambda x, s: jax.device_put(x, s), state["v"], p_sh),
                       "step": state["step"]}
            step = jax.jit(make_train_step(cfg, opt),
                           in_shardings=(p_sh, {"m": p_sh, "v": p_sh, "step": None}, None),
                           out_shardings=(p_sh, {"m": p_sh, "v": p_sh, "step": None}, None))
            p_new, _, m = step(params_d, state_d, batch)
        assert abs(float(m["loss"]) - float(m_ref["loss"])) < 1e-3, (m, m_ref)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_new)))
        assert d < 5e-3, d
        print("OK sharded==single", d)
        """
    )


def test_gpipe_matches_sequential():
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding.pipeline_parallel import gpipe

        P_STAGES, N_MICRO, MB, D = 4, 8, 2, 16
        mesh = jax.make_mesh((P_STAGES,), ("pipe",))
        ks = jax.random.split(jax.random.PRNGKey(0), P_STAGES)
        ws = jnp.stack([jax.random.normal(k, (D, D)) / jnp.sqrt(D) for k in ks])
        xs = jax.random.normal(jax.random.PRNGKey(1), (N_MICRO, MB, D))

        def stage(w, x):
            return jnp.tanh(x @ w)

        out = gpipe(stage, ws, xs, mesh, axis="pipe")
        ref = xs
        for i in range(P_STAGES):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        print("OK gpipe")
        """
    )


def test_compressed_grad_allreduce_close_to_exact():
    run_sub(
        """
        import jax, jax.numpy as jnp
        from repro.train.grad_compress import compressed_mean_grads

        mesh = jax.make_mesh((8,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32))

        from jax.sharding import NamedSharding, PartitionSpec as P
        gd = jax.device_put(g, NamedSharding(mesh, P("data")))
        out = compressed_mean_grads({"w": gd}, mesh, dp_axes=("data",))["w"]
        exact = jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape)
        rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.02, rel
        print("OK compress", rel)
        """
    )


def test_mini_dryrun_cell_with_roofline():
    """End-to-end dry-run machinery on a small mesh + smoke config: lower,
    compile, memory/cost analysis, trip-count-corrected roofline terms."""
    run_sub(
        """
        import jax, json
        import dataclasses
        from repro.configs.registry import get_smoke_config
        from repro.launch.dryrun import _shard_tree
        from repro.launch.hlo_analysis import roofline_terms
        from repro.models import init_params, param_logical_axes
        from repro.sharding.partitioning import DEFAULT_RULES, axis_rules
        from repro.sharding.compat import set_mesh
        from repro.train import OptConfig, make_train_step
        from repro.train.optimizer import adamw_init

        cfg = dataclasses.replace(get_smoke_config("gemma2-9b"), grad_accum=2)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pshape = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))
        p_sh = _shard_tree(param_logical_axes(cfg), mesh, DEFAULT_RULES, pshape)
        oshape = jax.eval_shape(adamw_init, pshape)
        o_sh = {"m": p_sh, "v": p_sh, "step": None}
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jax.numpy.int32),
            "labels": jax.ShapeDtypeStruct((8, 32), jax.numpy.int32),
        }
        with axis_rules(DEFAULT_RULES), set_mesh(mesh):
            lowered = jax.jit(make_train_step(cfg, OptConfig()),
                              in_shardings=(p_sh, o_sh, None)).lower(pshape, oshape, batch)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        roof = roofline_terms(compiled.cost_analysis(), compiled.as_text())
        assert roof.flops_per_chip > 0
        assert roof.hbm_bytes_per_chip > 0
        # accum scan x layer scan must be trip-count multiplied: raw cost
        # analysis undercounts vs the structural model
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax<=0.4.x: list per device
            ca = ca[0] if ca else {}
        raw = ca.get("flops", 0.0)
        assert roof.flops_per_chip > 1.5 * raw, (roof.flops_per_chip, raw)
        print("OK dryrun", roof.dominant)
        """
    )


def test_elastic_restore_across_meshes():
    run_sub(
        """
        import tempfile, jax, jax.numpy as jnp
        from repro.checkpoint import CheckpointManager
        from repro.checkpoint.elastic import elastic_restore, train_state_shardings
        from repro.configs.registry import get_smoke_config
        from repro.models import init_params
        from repro.train.optimizer import adamw_init

        cfg = get_smoke_config("stablelm-1.6b")
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(3, {"params": params, "opt": opt}, {"step": 3})
            # restore onto a DIFFERENT topology (4x2 vs training's 1 device)
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            p2, o2, meta = elastic_restore(mgr, cfg, mesh)
            assert meta["step"] == 3
            ok = jax.tree.map(lambda a, b: bool(jnp.allclose(a, jnp.asarray(b))), params, p2)
            assert all(jax.tree.leaves(ok))
            # leaves actually live on the new mesh
            leaf = jax.tree.leaves(p2)[0]
            assert len(leaf.devices()) > 1 or leaf.sharding.num_devices == 8
        print("OK elastic")
        """
    )


def test_ring_attention_matches_plain():
    run_sub(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import AttnSpec
        from repro.models.attention import _sdpa_plain
        from repro.sharding.ring_attention import ring_attention

        mesh = jax.make_mesh((8,), ("data",))
        B, S, H, KV, D = 2, 64, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        for spec, cap in [
            (AttnSpec(kind="global"), 0.0),
            (AttnSpec(kind="global", causal=False), 0.0),
            (AttnSpec(kind="local", window=24), 0.0),
            (AttnSpec(kind="global"), 30.0),
        ]:
            ref = _sdpa_plain(q, k, v, pos, pos, spec, cap)
            out = ring_attention(q, k, v, pos, spec, mesh, axis="data", softcap=cap)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
        print("OK ring attention")
        """
    )
