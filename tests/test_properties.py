"""Property-based tests (hypothesis) for the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is not part of the pinned container image; skip (don't fail
# collection) where it is unavailable rather than adding a dependency.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    BGConfig,
    bilateral_filter,
    bilateral_grid_filter,
    bilateral_grid_filter_fixed,
    bilateral_grid_filter_streaming,
    grid_create,
    mssim,
)

SETTINGS = dict(max_examples=20, deadline=None)


def _image(draw, hmin=8, hmax=40):
    h = draw(st.integers(hmin, hmax))
    w = draw(st.integers(hmin, hmax))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, 256, size=(h, w)).astype(np.float32)
    )


images = st.composite(_image)
radii = st.integers(1, 8)
sigmas_s = st.floats(0.5, 16.0, allow_nan=False)
sigmas_r = st.floats(5.0, 120.0, allow_nan=False)


@given(images(), radii, sigmas_s, sigmas_r)
@settings(**SETTINGS)
def test_grid_mass_conservation(img, r, ss, sr):
    c = BGConfig(r=r, sigma_s=ss, sigma_r=sr)
    g = grid_create(img, c)
    assert float(jnp.sum(g[..., 0])) == img.shape[0] * img.shape[1]
    np.testing.assert_allclose(
        float(jnp.sum(g[..., 1])), float(jnp.sum(img)), rtol=1e-5
    )


@given(images(), radii, sigmas_s, sigmas_r)
@settings(**SETTINGS)
def test_classic_mode_output_within_input_range(img, r, ss, sr):
    """Homogeneous normalization is a convex combination of cell averages."""
    c = BGConfig(r=r, sigma_s=ss, sigma_r=sr, normalize_mode="classic")
    out = bilateral_grid_filter(img, c, quantize_output=False)
    assert float(jnp.min(out)) >= float(jnp.min(img)) - 1e-2
    assert float(jnp.max(out)) <= float(jnp.max(img)) + 1e-2


@given(images(), radii, sigmas_s, sigmas_r)
@settings(**SETTINGS)
def test_paper_mode_output_in_intensity_range(img, r, ss, sr):
    c = BGConfig(r=r, sigma_s=ss, sigma_r=sr)
    out = bilateral_grid_filter(img, c)
    assert float(jnp.min(out)) >= 0.0 and float(jnp.max(out)) <= 255.0
    # quantized output is integral
    arr = np.asarray(out)
    np.testing.assert_array_equal(arr, np.floor(arr))


@given(
    st.integers(0, 255).map(float),
    st.integers(12, 40),
    st.integers(12, 40),
    radii,
    sigmas_s,
    sigmas_r,
)
@settings(**SETTINGS)
def test_constant_image_invariance(level, h, w, r, ss, sr):
    """Any bilateral-type filter must leave constant images untouched.

    Known paper-mode sensitivity (admitted in the paper's conclusion and
    reproduced here): when sigma_g = sigma_s/r is tiny the 3^3 blur taps
    underflow, neighbor z-cells stay empty, eq. (4) zeroes them, and TI leaks
    toward 0. The invariance therefore only holds for paper-mode when the
    blur actually populates the 1-neighborhood; classic mode and the BF are
    unconditionally invariant.
    """
    img = jnp.full((h, w), level)
    c_classic = BGConfig(r=r, sigma_s=ss, sigma_r=sr, normalize_mode="classic")
    np.testing.assert_allclose(
        np.asarray(bilateral_grid_filter(img, c_classic)), level, atol=0
    )
    # Paper-mode invariance needs the 3^3 blur to populate even the diagonal
    # (1,1,1) neighbors above the empty-cell threshold: tap^3 = e^{-3/(2 sg^2)}
    # >= 1e-12 requires sigma_g = ss/r >= ~0.25. Below that, eq. (4) zeroes
    # diagonal corners and TI leaks toward 0 — the sensitivity the paper's
    # conclusion admits.
    if ss / r >= 0.25:
        c_paper = BGConfig(r=r, sigma_s=ss, sigma_r=sr, normalize_mode="paper")
        np.testing.assert_allclose(
            np.asarray(bilateral_grid_filter(img, c_paper)), level, atol=0
        )
    np.testing.assert_allclose(
        np.asarray(bilateral_filter(img, min(r, 5), ss, sr)), level, atol=0
    )


@given(images(hmin=10, hmax=32), radii, sigmas_s, sigmas_r)
@settings(max_examples=10, deadline=None)
def test_streaming_equals_batch_property(img, r, ss, sr):
    c = BGConfig(r=r, sigma_s=ss, sigma_r=sr)
    a = bilateral_grid_filter(img, c, quantize_output=False)
    b = bilateral_grid_filter_streaming(img, c, quantize_output=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@given(images(), st.integers(1, 6), sigmas_s, sigmas_r)
@settings(**SETTINGS)
def test_bf_output_within_input_range(img, r, ss, sr):
    out = bilateral_filter(img, r, ss, sr, quantize_output=False)
    assert float(jnp.min(out)) >= float(jnp.min(img)) - 1e-3
    assert float(jnp.max(out)) <= float(jnp.max(img)) + 1e-3


@given(images(hmin=16, hmax=32), st.integers(2, 16), sigmas_s, sigmas_r)
@settings(**SETTINGS)
def test_fixed_point_integer_range(img, r, ss, sr):
    c = BGConfig(r=r, sigma_s=ss, sigma_r=sr, weight_mode="pow2")
    out = np.asarray(bilateral_grid_filter_fixed(img, c))
    assert out.min() >= 0 and out.max() <= 255
    np.testing.assert_array_equal(out, np.floor(out))


@given(images(hmin=16, hmax=32))
@settings(**SETTINGS)
def test_mssim_bounds(img):
    assert float(mssim(img, img)) > 0.9999
    other = 255.0 - img
    v = float(mssim(img, other))
    assert -1.0 <= v <= 1.0
