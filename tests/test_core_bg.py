"""Core bilateral-grid behaviour: paper-claim validation + implementation
equivalences (batch == streaming == fixed-point within LSB)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BGConfig,
    add_gaussian_noise,
    bilateral_filter,
    bilateral_grid_filter,
    bilateral_grid_filter_fixed,
    bilateral_grid_filter_streaming,
    grid_blur,
    grid_create,
    grid_shape,
    mssim,
    psnr,
    synthetic_image,
)

H, W = 96, 128
IMG = synthetic_image(H, W)
NOISY = add_gaussian_noise(IMG, 30.0)


def cfg(r=7, ss=4.0, sr=50.0, **kw):
    return BGConfig(r=r, sigma_s=ss, sigma_r=sr, **kw)


# ---------------------------------------------------------------- grid basics
def test_grid_shape_matches_paper_formula():
    c = cfg(r=12, ss=8.0, sr=70.0)
    gx, gy, gz = grid_shape(1080, 1920, c)
    assert (gx, gy) == (1080 // 12 + 2, 1920 // 12 + 2)
    assert gz == int(np.floor(255.0 / (12 * 70.0 / 8.0))) + 2


def test_grid_create_conservation():
    """Sum of counts == #pixels; sum of sums == sum of image (mass is moved,
    never created)."""
    c = cfg()
    g = grid_create(NOISY, c)
    assert float(jnp.sum(g[..., 0])) == H * W
    np.testing.assert_allclose(
        float(jnp.sum(g[..., 1])), float(jnp.sum(NOISY)), rtol=1e-6
    )


def test_grid_blur_preserves_mass():
    """With zero-padded borders the 3^3 blur only loses mass at the (empty)
    boundary planes; interior mass is weighted identically for both channels."""
    c = cfg()
    g = grid_create(NOISY, c)
    b = grid_blur(g, c)
    # blur weights are positive; counts stay positive wherever they were
    assert float(jnp.min(b)) >= 0.0
    # both channels blurred with identical taps: ratio bounded by intensities
    ratio = b[..., 1] / jnp.maximum(b[..., 0], 1e-12)
    assert float(jnp.max(ratio)) <= 255.0 + 1e-3


# ------------------------------------------------------- output-quality claims
def test_bg_denoises():
    out = bilateral_grid_filter(NOISY, cfg())
    assert float(mssim(IMG, out)) > float(mssim(IMG, NOISY)) + 0.2


def test_bg_matches_bf_quality_band():
    """Fig. 12: with proper parameters the BG reaches BF-equivalent MSSIM."""
    out_bg = bilateral_grid_filter(NOISY, cfg())
    out_bf = bilateral_filter(NOISY, 7, 4.0, 50.0)
    m_bg = float(mssim(IMG, out_bg))
    m_bf = float(mssim(IMG, out_bf))
    assert m_bg > m_bf - 0.05, (m_bg, m_bf)


def test_bg_output_range():
    out = bilateral_grid_filter(NOISY, cfg())
    assert float(jnp.min(out)) >= 0.0 and float(jnp.max(out)) <= 255.0


def test_constant_image_fixed_point():
    """A constant image is a fixed point of any bilateral filter."""
    flat = jnp.full((64, 64), 131.0)
    for mode in ("paper", "classic"):
        out = bilateral_grid_filter(flat, cfg(normalize_mode=mode))
        np.testing.assert_allclose(np.asarray(out), 131.0)


def test_classic_vs_paper_normalization_close():
    a = bilateral_grid_filter(NOISY, cfg(normalize_mode="paper"), quantize_output=False)
    b = bilateral_grid_filter(NOISY, cfg(normalize_mode="classic"), quantize_output=False)
    # same filter up to the normalization-order approximation
    assert float(jnp.mean(jnp.abs(a - b))) < 10.0


# ----------------------------------------------------- implementation parity
@pytest.mark.parametrize("mode", ["paper", "classic"])
@pytest.mark.parametrize("r", [2, 5, 7, 12])
def test_streaming_equals_batch(mode, r):
    c = cfg(r=r, normalize_mode=mode)
    batch = bilateral_grid_filter(NOISY, c, quantize_output=False)
    stream = bilateral_grid_filter_streaming(NOISY, c, quantize_output=False)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(batch), atol=1e-3)


def test_streaming_non_multiple_height():
    img = NOISY[: H - 5]
    c = cfg(r=7)
    batch = bilateral_grid_filter(img, c, quantize_output=False)
    stream = bilateral_grid_filter_streaming(img, c, quantize_output=False)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(batch), atol=1e-3)


@pytest.mark.parametrize("r", [4, 8, 12, 16])
def test_fixed_point_matches_pow2_float(r):
    """Shift-only integer datapath agrees with pow2-float within 1 LSB
    almost everywhere (quantization of interp coefficients)."""
    cf = cfg(r=r, ss=8.0, sr=70.0, weight_mode="pow2")
    ref = bilateral_grid_filter(NOISY, cf)
    fx = bilateral_grid_filter_fixed(NOISY, cf)
    diff = np.abs(np.asarray(ref) - np.asarray(fx))
    assert np.mean(diff <= 1.0) > 0.99, np.mean(diff)
    assert diff.max() <= 4.0


def test_pow2_weights_quality_close_to_float():
    """Paper claim: shift-only arithmetic does not hurt denoising quality."""
    m_float = float(mssim(IMG, bilateral_grid_filter(NOISY, cfg())))
    m_pow2 = float(
        mssim(IMG, bilateral_grid_filter(NOISY, cfg(weight_mode="pow2")))
    )
    assert abs(m_float - m_pow2) < 0.05


# --------------------------------------------------------------------- metrics
def test_mssim_identity_and_symmetry():
    assert float(mssim(IMG, IMG)) == pytest.approx(1.0, abs=1e-5)
    assert float(mssim(IMG, NOISY)) == pytest.approx(float(mssim(NOISY, IMG)), abs=1e-5)
    assert float(mssim(IMG, NOISY)) < 0.9


def test_psnr_identity():
    assert float(psnr(IMG, IMG)) > 100.0
    assert 5.0 < float(psnr(IMG, NOISY)) < 30.0


def test_bf_reference_properties():
    """BF sanity: constant image fixed-point; denoises; stays in range."""
    flat = jnp.full((48, 48), 77.0)
    np.testing.assert_allclose(np.asarray(bilateral_filter(flat, 5, 3.0, 40.0)), 77.0)
    out = bilateral_filter(NOISY, 7, 4.0, 50.0)
    assert float(mssim(IMG, out)) > float(mssim(IMG, NOISY))
    assert float(jnp.min(out)) >= 0.0 and float(jnp.max(out)) <= 255.0
