"""Temporal bilateral grid + stream sessions.

The contracts under test:
  * ``alpha == 0`` is the per-frame fused service path, *bit-identically*,
    across ragged multi-stream shapes (h % r != 0, w % r != 0, n odd) — the
    temporal subsystem must cost nothing when switched off;
  * a warm-up pack (``alpha > 0``, no history) is bit-identical to the
    per-frame fused path (effective alpha 0 on the fused temporal kernel),
    while the ``staged=True`` oracle still equals the jnp reference exactly;
  * on a static scene, PSNR improves monotonically with alpha (the EMA
    accumulates evidence instead of flickering);
  * per-stream carries never leak across streams in the multi-stream packer;
  * the ``synthetic_video`` fixture is deterministic and actually pans.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BGConfig, add_gaussian_noise, bilateral_grid_filter, psnr
from repro.core.bilateral_grid import quantize_intensity
from repro.data import synthetic_video
from repro.kernels import bg_fused
from repro.video import (
    MultiStreamPacker,
    blurred_grid_batch,
    carry_shape,
    temporal_denoise,
)

CFG = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)

# ragged (h, w) wrt r=6, stream counts covering n == 1 and odd packs
RAGGED_PACKS = [((45, 55), 1), ((45, 55), 3), ((33, 47), 5)]


def _noisy_stack(n, h, w, seed=0):
    vid = synthetic_video(seed, n, h, w, motion=1.5)
    return jnp.stack(
        [add_gaussian_noise(vid[t], 30.0, seed=seed + 10 * t) for t in range(n)]
    )


@pytest.mark.parametrize("shape,n", RAGGED_PACKS)
def test_alpha0_bit_identical_to_fused_per_frame(shape, n):
    h, w = shape
    assert h % CFG.r and w % CFG.r  # genuinely ragged
    frames = _noisy_stack(n, h, w)
    out, carry = temporal_denoise(frames, CFG, alpha=0.0, interpret=True)
    assert carry is None  # nothing temporal was computed
    ref = quantize_intensity(bg_fused(frames, CFG, interpret=True), CFG)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_alpha0_single_frame_squeeze():
    frame = _noisy_stack(1, 45, 55)[0]
    out, carry = temporal_denoise(frame, CFG, alpha=0.0, interpret=True)
    assert out.shape == frame.shape and carry is None
    ref = quantize_intensity(bg_fused(frame, CFG, interpret=True), CFG)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_warmup_pack_matches_fused_per_frame():
    """alpha > 0 with no history: effective alpha 0 on the fused temporal
    kernel — bit-identical to the per-frame fused path, and must emit a
    carry. The staged oracle (staged=True) still equals the jnp reference
    exactly, and the fused carry tracks the staged carry."""
    frames = _noisy_stack(3, 45, 55)
    out, carry = temporal_denoise(frames, CFG, alpha=0.5, interpret=True)
    assert carry.shape == (3,) + carry_shape(45, 55, CFG)
    ref = quantize_intensity(bg_fused(frames, CFG, interpret=True), CFG)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    out_s, carry_s = temporal_denoise(frames, CFG, alpha=0.5, staged=True)
    ref_s = jnp.stack([bilateral_grid_filter(frames[i], CFG) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(ref_s))
    np.testing.assert_allclose(
        np.asarray(carry), np.asarray(carry_s), atol=2e-2, rtol=1e-4
    )


def test_blurred_grid_batch_matches_per_frame_reference():
    """The hoisted batched GC+GF (shared cell indices/taps, one batched
    scatter + batched convs) must equal the per-frame staged pipeline
    exactly — it is the definition of the quantity the EMA carries."""
    from repro.core.bilateral_grid import grid_blur, grid_create

    frames = _noisy_stack(4, 33, 47)
    ref = jnp.stack([grid_blur(grid_create(f, CFG), CFG) for f in frames])
    np.testing.assert_array_equal(
        np.asarray(blurred_grid_batch(frames, CFG)), np.asarray(ref)
    )


def test_alpha_validation():
    frames = _noisy_stack(2, 33, 47)
    for bad in (-0.1, 1.0, 1.5):
        with pytest.raises(ValueError):
            temporal_denoise(frames, CFG, alpha=bad)
    with pytest.raises(ValueError):  # carry/frames stream mismatch
        carry = jnp.zeros((3,) + carry_shape(33, 47, CFG))
        temporal_denoise(frames, CFG, carry=carry, alpha=0.5)


def test_static_scene_psnr_monotone_in_alpha():
    cfg = BGConfig(r=4, sigma_s=4.0, sigma_r=60.0)
    clean = synthetic_video(1, 1, 48, 64, motion=0.0)[0]
    vals = []
    for alpha in (0.0, 0.3, 0.6, 0.8):
        packer = MultiStreamPacker(cfg)
        packer.open(0, alpha=alpha)
        for t in range(12):
            out = packer.pack({0: add_gaussian_noise(clean, 30.0, seed=100 + t)})[0]
        vals.append(float(psnr(clean, out)))
    assert all(b > a for a, b in zip(vals, vals[1:])), vals


def test_packer_no_cross_stream_leak():
    """Stream A denoised in a pack with B must equal A packed alone — the
    stacked carry rows belong to exactly one stream each."""
    cfg = BGConfig(r=4, sigma_s=4.0, sigma_r=60.0)
    nA = _noisy_stack(5, 40, 56, seed=3)
    nB = _noisy_stack(5, 40, 56, seed=7)
    solo = MultiStreamPacker(cfg)
    solo.open("A", alpha=0.5)
    solo_out = [solo.pack({"A": nA[t]})["A"] for t in range(5)]
    duo = MultiStreamPacker(cfg)
    duo.open("A", alpha=0.5)
    duo.open("B", alpha=0.7)
    for t in range(5):
        outs = duo.pack({"A": nA[t], "B": nB[t]})
        np.testing.assert_array_equal(np.asarray(solo_out[t]), np.asarray(outs["A"]))
    assert duo.sessions["A"].frames_seen == duo.sessions["B"].frames_seen == 5


def test_packer_mixed_alpha_and_zero_alpha_carry_free():
    """alpha == 0 sessions never hold a carry and stay bit-identical to the
    fused per-frame path even when packed WITH warm streams (batch
    composition is timing-dependent under the async engine, so cold-stream
    bits must not depend on it); mixed packs still advance the temporal
    sessions; an all-zero-alpha pack is the fused path (no carries
    materialized anywhere)."""
    packer = MultiStreamPacker(CFG, interpret=True)
    packer.open("warm", alpha=0.6)
    packer.open("cold", alpha=0.0)
    frames = _noisy_stack(2, 33, 47)
    fused_ref = quantize_intensity(bg_fused(frames, CFG, interpret=True), CFG)
    for t in range(2):
        outs = packer.pack({"warm": frames[t], "cold": frames[t]})
        np.testing.assert_array_equal(
            np.asarray(outs["cold"]), np.asarray(fused_ref[t])
        )
    assert packer.sessions["warm"].carry is not None
    assert packer.sessions["cold"].carry is None

    allzero = MultiStreamPacker(CFG, interpret=True)
    allzero.open(0)
    allzero.open(1)
    out = allzero.pack({0: frames[0], 1: frames[1]})
    ref = quantize_intensity(bg_fused(frames, CFG, interpret=True), CFG)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(ref[1]))
    assert allzero.sessions[0].carry is None


def test_packer_errors():
    packer = MultiStreamPacker(CFG)
    packer.open("a", alpha=0.2)
    with pytest.raises(ValueError):
        packer.open("a")  # double open
    with pytest.raises(ValueError):
        packer.open("bad", alpha=1.0)  # alpha out of range, session not added
    with pytest.raises(KeyError):
        packer.pack({"ghost": jnp.zeros((24, 24))})
    packer.open("b", alpha=0.2)
    with pytest.raises(ValueError):  # mismatched frame shapes in one pack
        packer.pack({"a": jnp.zeros((24, 24)), "b": jnp.zeros((30, 24))})
    assert packer.pack({}) == {}
    packer.close("b")
    assert packer.live() == 1  # only "a" remains


def test_synthetic_video_fixture():
    a = synthetic_video(5, 4, 40, 60, motion=2.0)
    b = synthetic_video(5, 4, 40, 60, motion=2.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))  # deterministic
    assert a.shape == (4, 40, 60)
    # panning: frame 1 shifted by `motion` overlaps frame 0 exactly
    np.testing.assert_array_equal(
        np.asarray(a[1][: 40 - 2, : 60 - 2]), np.asarray(a[0][2:, 2:])
    )
    static = synthetic_video(5, 3, 40, 60, motion=0.0)
    np.testing.assert_array_equal(np.asarray(static[0]), np.asarray(static[2]))
    with pytest.raises(ValueError):
        synthetic_video(0, 0, 40, 60)
