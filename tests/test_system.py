"""End-to-end system behaviour: train -> checkpoint -> resume -> serve, and
the paper's denoiser running inside the data pipeline."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bg_denoise import PAPER_DEFAULT, TABLE1_SWEEP
from repro.configs.registry import get_smoke_config
from repro.core import (
    BGConfig,
    add_gaussian_noise,
    bilateral_grid_filter,
    mssim,
    synthetic_image,
)
from repro.data import denoise_batch, lm_batches, vlm_preprocess
from repro.serving import Request, ServeEngine
from repro.train import OptConfig, Trainer


def test_train_checkpoint_serve_roundtrip():
    """The full lifecycle on one config: a few train steps, checkpoint,
    resume into a serving engine, generate deterministically."""
    cfg = get_smoke_config("yi-6b")
    opt = OptConfig(lr=1e-3, warmup_steps=2, decay_steps=20)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, opt, d, ckpt_every=5)
        tr.init_or_resume()
        batches = (
            {k: jnp.asarray(v) for k, v in b.items()}
            for b in lm_batches(cfg.vocab_size, 4, 16, 10, seed=3)
        )
        tr.run(batches, max_steps=10)

        tr2 = Trainer(cfg, opt, d)
        assert tr2.init_or_resume() == "resumed" and tr2.step == 10
        eng = ServeEngine(cfg, tr2.params, max_slots=2, max_len=48)
        reqs = [Request(uid=i, prompt=[1 + i, 2, 3], max_tokens=5) for i in range(2)]
        for r in reqs:
            assert eng.submit(r)
        eng.run_to_completion()
        assert all(len(r.generated) == 5 for r in reqs)
        # same params, same prompts => deterministic outputs
        eng2 = ServeEngine(cfg, tr2.params, max_slots=2, max_len=48)
        reqs2 = [Request(uid=i, prompt=[1 + i, 2, 3], max_tokens=5) for i in range(2)]
        for r in reqs2:
            eng2.submit(r)
        eng2.run_to_completion()
        assert [r.generated for r in reqs] == [r.generated for r in reqs2]


def test_bg_denoise_in_data_pipeline():
    """The paper's technique as a pipeline stage: batched denoise improves
    MSSIM for every image in the batch; VLM preprocessing runs end to end."""
    clean = jnp.stack([synthetic_image(64, 96, seed=i) for i in range(3)])
    noisy = jnp.stack(
        [add_gaussian_noise(clean[i], 30.0, seed=10 + i) for i in range(3)]
    )
    cfg = BGConfig(r=4, sigma_s=3.0, sigma_r=50.0)
    den = denoise_batch(noisy, cfg)
    for i in range(3):
        assert float(mssim(clean[i], den[i])) > float(mssim(clean[i], noisy[i]))
    ctx = vlm_preprocess(noisy, cfg, patch=16, dim=32)
    assert ctx.shape == (3, (64 // 16) * (96 // 16), 32)
    assert bool(jnp.all(jnp.isfinite(ctx)))


def test_paper_workload_presets():
    """The paper's own configs are well-formed and runnable at reduced size."""
    assert PAPER_DEFAULT.bg.r == 12 and PAPER_DEFAULT.height == 1080
    assert tuple(w.bg.r for w in TABLE1_SWEEP) == (4, 8, 12, 16)
    img = add_gaussian_noise(synthetic_image(60, 80), 30.0)
    out = bilateral_grid_filter(img, TABLE1_SWEEP[0].bg)
    assert out.shape == (60, 80)
