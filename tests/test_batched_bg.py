"""Batched throughput path validation.

The fused kernel's (batch, stripe) grid must be invisible numerically:
  * every frame of a batch matches the pure-jnp oracle `ref.ref_fused`,
    including ragged shapes (h % r != 0, w % r != 0);
  * the degenerate b == 1 batch is bit-identical to the single-frame path;
  * batch-tile padding (b not divisible by the tile) never leaks the zero
    padding frames into real outputs;
  * the batched wrappers (pallas pipeline, streaming scan, data pipeline)
    agree with their per-frame equivalents.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BGConfig,
    add_gaussian_noise,
    bilateral_grid_filter_streaming,
    synthetic_batch,
)
from repro.kernels import bg_fused, bilateral_grid_filter_pallas
from repro.kernels.ref import ref_fused

# ragged shapes: every (shape, r) pair has h % r != 0 and w % r != 0
RAGGED = [
    ((61, 83), 7),
    ((45, 200), 6),
    ((33, 47), 4),
]


def _batch(b, h, w, seed=0):
    return add_gaussian_noise(synthetic_batch(b, h, w, seed=seed), 30.0, seed=seed + 50)


@pytest.mark.parametrize("shape,r", RAGGED)
@pytest.mark.parametrize("b", [1, 3])
def test_batched_fused_matches_ref_ragged(shape, r, b):
    h, w = shape
    assert h % r != 0 and w % r != 0  # the matrix is genuinely ragged
    cfg = BGConfig(r=r, sigma_s=4.0, sigma_r=60.0)
    imgs = _batch(b, h, w)
    out = bg_fused(imgs, cfg, interpret=True)
    assert out.shape == (b, h, w)
    for i in range(b):
        ref = ref_fused(imgs[i], cfg)
        err = float(jnp.max(jnp.abs(out[i] - ref)))
        assert err <= 1e-4, f"frame {i}: max abs err {err}"


@pytest.mark.parametrize("shape,r", RAGGED)
def test_degenerate_batch_bitwise_single_frame(shape, r):
    """b == 1 must be bit-identical to the (h, w) single-frame call."""
    h, w = shape
    cfg = BGConfig(r=r, sigma_s=4.0, sigma_r=60.0)
    img = _batch(1, h, w)[0]
    single = bg_fused(img, cfg, interpret=True)
    batched = bg_fused(img[None], cfg, interpret=True)
    assert batched.shape == (1, h, w)
    np.testing.assert_array_equal(np.asarray(batched[0]), np.asarray(single))


def test_batch_tile_padding_is_masked():
    """b=5 with tile=2 pads to 6 frames; padding must not perturb any frame
    (each tile sweeps its own grid steps, so results stay bit-identical)."""
    cfg = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)
    imgs = _batch(5, 40, 55)
    out = bg_fused(imgs, cfg, interpret=True, batch_tile=2)
    for i in range(5):
        np.testing.assert_array_equal(
            np.asarray(out[i]), np.asarray(bg_fused(imgs[i], cfg, interpret=True))
        )


@pytest.mark.parametrize("fused", [True, False])
def test_batched_pipeline_wrapper_matches_per_frame(fused):
    cfg = BGConfig(r=7, sigma_s=4.0, sigma_r=50.0)
    imgs = _batch(3, 45, 64)
    out = bilateral_grid_filter_pallas(imgs, cfg, fused=fused, interpret=True)
    assert out.shape == imgs.shape
    for i in range(3):
        ref = bilateral_grid_filter_pallas(imgs[i], cfg, fused=fused, interpret=True)
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(ref))


def test_batched_streaming_matches_per_frame():
    cfg = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)
    imgs = _batch(3, 40, 55)
    out = bilateral_grid_filter_streaming(imgs, cfg)
    assert out.shape == imgs.shape
    for i in range(3):
        ref = bilateral_grid_filter_streaming(imgs[i], cfg)
        np.testing.assert_allclose(
            np.asarray(out[i]), np.asarray(ref), atol=1e-5
        )


def test_denoise_batch_kernel_path():
    """data-pipeline stage feeds the batch natively to the fused kernel and
    stays within 1 quantized level of the vmapped jnp reference."""
    from repro.data.pipeline import denoise_batch

    cfg = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)
    imgs = _batch(2, 40, 55)
    out_k = denoise_batch(imgs, cfg, use_kernels=True)
    out_j = denoise_batch(imgs, cfg, use_kernels=False)
    diff = np.abs(np.asarray(out_k) - np.asarray(out_j))
    assert np.mean(diff == 0.0) > 0.995
    assert diff.max() <= 1.0


@pytest.mark.parametrize("shape,r", RAGGED)
@pytest.mark.parametrize("b", [1, 3])
def test_stream_input_bitwise_default_path(shape, r, b):
    """The explicit double-buffered HBM->VMEM input path must be numerically
    invisible: bit-identical to the automatically pipelined default, including
    ragged shapes, batch-tile padding and the drain steps."""
    h, w = shape
    cfg = BGConfig(r=r, sigma_s=4.0, sigma_r=60.0)
    imgs = _batch(b, h, w)
    base = bg_fused(imgs, cfg, interpret=True, batch_tile=2)
    stream = bg_fused(imgs, cfg, interpret=True, batch_tile=2, stream_input=True)
    np.testing.assert_array_equal(np.asarray(stream), np.asarray(base))


def test_stream_input_single_frame_squeeze():
    cfg = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)
    img = _batch(1, 40, 55)[0]
    np.testing.assert_array_equal(
        np.asarray(bg_fused(img, cfg, interpret=True, stream_input=True)),
        np.asarray(bg_fused(img, cfg, interpret=True)),
    )


@pytest.mark.parametrize("use_kernels", [True, False])
def test_color_frames_fold_channels_into_batch(use_kernels):
    """(b, h, w, 3) color batches denoise per channel by folding the channel
    axis into the batch axis; round-trips bit-exactly against denoising each
    channel plane separately."""
    from repro.data.pipeline import denoise_batch

    cfg = BGConfig(r=6, sigma_s=4.0, sigma_r=60.0)
    base = _batch(3, 40, 55)
    # three genuinely different channel planes per frame
    color = jnp.stack(
        [base, jnp.flip(base, axis=1), jnp.flip(base, axis=2)], axis=-1
    )
    out = denoise_batch(color, cfg, use_kernels=use_kernels)
    assert out.shape == color.shape
    per_channel = jnp.stack(
        [
            denoise_batch(color[..., c], cfg, use_kernels=use_kernels)
            for c in range(3)
        ],
        axis=-1,
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(per_channel))
